"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, exact equality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BitPlanarDB, build_database, msb_nibble, quantize_int8
from repro.kernels import ops, ref
from repro.kernels.fused_topk import fused_topk_pallas
from repro.kernels.stage1_int4 import stage1_int4_pallas


def make(n, d, seed=0):
    rng = np.random.default_rng(seed)
    db = build_database(jnp.asarray(
        rng.normal(size=(n, d)).astype(np.float32)))
    bp = BitPlanarDB.from_quantized(db)
    q, _ = quantize_int8(jnp.asarray(rng.normal(size=(d,)).astype(np.float32)))
    return db, bp, q


@pytest.mark.parametrize("n,d,block", [(256, 512, 64), (512, 512, 256),
                                       (128, 256, 128), (1024, 128, 256),
                                       (96, 512, 32)])
def test_stage1_kernel_shape_sweep(n, d, block):
    _, bp, q = make(n, d, seed=n + d)
    q_eo = ops.pack_query_even_odd(msb_nibble(q))
    got = stage1_int4_pallas(q_eo, bp.msb_plane, block_n=block)
    want = ref.stage1_scores_ref(q_eo, bp.msb_plane)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("c,d,block", [(64, 512, 64), (50, 512, 64),
                                       (128, 256, 32), (16, 128, 8)])
def test_stage2_kernel_shape_sweep(c, d, block):
    db, bp, q = make(max(c, 64), d, seed=c + d)
    cand = jnp.arange(c, dtype=jnp.int32)
    mr = jnp.take(bp.msb_plane, cand, axis=0)
    lr = jnp.take(bp.lsb_plane, cand, axis=0)
    got = ops.stage2_scores(q, mr, lr, block_c=block)
    want = ref.stage2_scores_ref(ops.pack_query_even_odd(q), mr, lr)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # exact INT8 ground truth
    exact = (np.asarray(db.values)[:c].astype(np.int64)
             @ np.asarray(q).astype(np.int64))
    np.testing.assert_array_equal(np.asarray(got, np.int64), exact)


@pytest.mark.parametrize("n,block,k", [(512, 128, 8), (1024, 256, 4),
                                       (256, 64, 16)])
def test_fused_topk_kernel(n, block, k):
    _, bp, q = make(n, 512, seed=n + k)
    q_eo = ops.pack_query_even_odd(msb_nibble(q))
    gs, gi = fused_topk_pallas(q_eo, bp.msb_plane, k=k, block_n=block)
    ws, wi = ref.fused_topk_ref(q_eo, bp.msb_plane, block, k)
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_fused_candidates_recall():
    """With k_per_block >= c the fused kernel's candidate SET equals the
    dense stage-1 top-c exactly."""
    _, bp, q = make(1000, 512, seed=9)
    q_msb = msb_nibble(q)
    cands = ops.fused_candidates(q_msb, bp.msb_plane, c=50, k_per_block=50,
                                 block_n=256)
    from repro.core.retrieval import stage1_scores_jnp
    scores = stage1_scores_jnp(q_msb, bp.msb_plane)
    true = jax.lax.top_k(scores, 50)[1]
    assert set(np.asarray(cands).tolist()) == set(np.asarray(true).tolist())


def test_stage1_wrapper_pads_nonmultiple():
    _, bp, q = make(250, 512, seed=11)    # 250 not a block multiple
    got = ops.stage1_scores(msb_nibble(q), bp.msb_plane)
    want = ref.stage1_scores_ref(ops.pack_query_even_odd(msb_nibble(q)),
                                 bp.msb_plane)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernels_accept_extreme_values():
    """All-(-128) codes: the nibble decomposition edge case."""
    codes = jnp.full((64, 512), -128, jnp.int8)
    from repro.core.bitplanar import pack_nibble_planes
    msb, lsb = pack_nibble_planes(codes)
    q = jnp.full((512,), -128, jnp.int8)
    got = ops.stage2_scores(q, msb, lsb)
    np.testing.assert_array_equal(np.asarray(got, np.int64),
                                  np.full(64, 512 * 128 * 128, np.int64))


# ---------------------------------------------------------------------------
# Batch-native kernels (the engine's backends)
# ---------------------------------------------------------------------------

def make_batch(n, d, b, seed=0):
    rng = np.random.default_rng(seed)
    db = build_database(jnp.asarray(
        rng.normal(size=(n, d)).astype(np.float32)))
    bp = BitPlanarDB.from_quantized(db)
    q, _ = quantize_int8(jnp.asarray(
        rng.normal(size=(b, d)).astype(np.float32)), per_vector=True)
    return db, bp, q


@pytest.mark.parametrize("n,d,b,block", [(256, 512, 8, 64), (512, 256, 1, 256),
                                         (96, 128, 32, 32), (250, 512, 4, 64)])
def test_stage1_batched_kernel_true_matmul(n, d, b, block):
    """The batched matmul kernel == per-lane oracle == vmapped scalar kernel
    (bit-for-bit: all paths are exact integer arithmetic)."""
    _, bp, q = make_batch(n, d, b, seed=n + d + b)
    q_msb = msb_nibble(q)
    got = ops.stage1_scores_batched(q_msb, bp.msb_plane, block_n=block)
    want = ref.stage1_scores_batched_ref(ops.pack_query_panel(q_msb),
                                         bp.msb_plane)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    vmapped = jax.vmap(lambda qm: ops.stage1_scores(qm, bp.msb_plane,
                                                    block_n=block))(q_msb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(vmapped))


@pytest.mark.parametrize("b,w,d,block", [(4, 128, 256, 64), (2, 64, 512, 64),
                                         (8, 96, 128, 32)])
def test_stage1_rows_kernel_per_lane_windows(b, w, d, block):
    """Each lane scores its OWN row block (the windowed-policy shape)."""
    _, bp, q = make_batch(w * b, d, b, seed=b + w + d)
    starts = np.arange(b) * w
    rows = jnp.stack([bp.msb_plane[s:s + w] for s in starts])
    q_msb = msb_nibble(q)
    got = ops.stage1_scores_rows(q_msb, rows, block_w=block)
    want = ref.stage1_rows_batched_ref(ops.pack_queries_even_odd(q_msb), rows)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,c,d,block", [(4, 50, 512, 64), (8, 64, 256, 32),
                                         (2, 16, 128, 8)])
def test_stage2_batched_kernel_one_launch(b, c, d, block):
    """(B, C) gathered candidates rescored in one launch, exact INT8."""
    db, bp, q = make_batch(max(c * b, 64), d, b, seed=b + c + d)
    rng = np.random.default_rng(b + c)
    cand = jnp.asarray(rng.integers(0, bp.num_docs, (b, c)), jnp.int32)
    mr = jnp.take(bp.msb_plane, cand, axis=0)
    lr = jnp.take(bp.lsb_plane, cand, axis=0)
    got = ops.stage2_scores_batched(q, mr, lr, block_c=block)
    want = ref.stage2_scores_batched_ref(ops.pack_queries_even_odd(q), mr, lr)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # exact INT8 ground truth per lane
    vals = np.asarray(db.values).astype(np.int64)
    qq = np.asarray(q).astype(np.int64)
    exact = np.stack([vals[np.asarray(cand)[i]] @ qq[i] for i in range(b)])
    np.testing.assert_array_equal(np.asarray(got, np.int64), exact)


@pytest.mark.parametrize("masked", [False, True])
def test_fused_topk_batched_kernel(masked):
    """Batch grid dimension + the tenant segment mask applied IN-kernel."""
    from repro.kernels.fused_topk import fused_topk_batched_pallas
    n, d, b, block, k = 512, 256, 4, 128, 8
    _, bp, q = make_batch(n, d, b, seed=17)
    q_eo = ops.pack_queries_even_odd(msb_nibble(q))
    rng = np.random.default_rng(3)
    owner = jnp.asarray(rng.integers(-1, 3, n), jnp.int32)
    tids = jnp.asarray([0, 1, 2, -2], jnp.int32)   # incl. a padding lane
    if masked:
        gs, gi = fused_topk_batched_pallas(q_eo, bp.msb_plane, owner, tids,
                                           k=k, block_n=block)
        ws, wi = ref.fused_topk_batched_ref(q_eo, bp.msb_plane, block, k,
                                            owner, tids)
    else:
        gs, gi = fused_topk_batched_pallas(q_eo, bp.msb_plane,
                                           k=k, block_n=block)
        ws, wi = ref.fused_topk_batched_ref(q_eo, bp.msb_plane, block, k)
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_fused_candidates_batched_masked_recall():
    """With k_per_block >= c the batched fused candidate SET equals each
    lane's dense masked stage-1 top-c exactly."""
    from repro.core.engine import stage1_plane_batched_jnp
    n, d, b, c = 512, 256, 3, 20
    _, bp, q = make_batch(n, d, b, seed=23)
    q_msb = msb_nibble(q)
    rng = np.random.default_rng(5)
    owner = jnp.asarray(rng.integers(0, 3, n), jnp.int32)
    tids = jnp.asarray([0, 1, 2], jnp.int32)
    cands = ops.fused_candidates_batched(q_msb, bp.msb_plane, owner, tids,
                                         c=c, k_per_block=c, block_n=128)
    scores = stage1_plane_batched_jnp(q_msb, bp.msb_plane)
    member = np.asarray(owner)[None, :] == np.asarray(tids)[:, None]
    # int64: negating INT32_MIN would overflow in int32
    masked = np.where(member, np.asarray(scores),
                      np.iinfo(np.int32).min).astype(np.int64)
    for i in range(b):
        true = set(np.argsort(-masked[i], kind="stable")[:c].tolist())
        assert set(np.asarray(cands)[i].tolist()) == true


@pytest.mark.parametrize("n,d,b,j,br", [(256, 256, 4, 6, 32),
                                        (512, 128, 8, 4, 64),
                                        (128, 512, 2, 8, 32)])
def test_stage1_gather_kernels_two_region_slab(n, d, b, j, br):
    """The scalar-prefetch gather kernel over a combined [plane | slab]
    array: slab-region blocks mirror plane blocks (the hot-cluster
    cache's fills), and scoring through either region is bit-equal to
    the oracles and to the plain-plane gather — the kernel is
    indifferent to WHICH region an id addresses."""
    _, bp, q = make_batch(n, d, b, seed=n + b)
    q_msb = msb_nibble(q)
    q_eo = ops.pack_queries_even_odd(q_msb)
    rng = np.random.default_rng(j)
    ids = jnp.asarray(rng.integers(0, n // br, (b, j)).astype(np.int32))
    # general wrapper == oracle (clamped/zero-mask convention)
    got = ops.stage1_scores_gather(q_msb, bp.msb_plane, ids, block_rows=br)
    want = ref.stage1_gather_batched_ref(q_eo, bp.msb_plane, ids, br)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # two-region slab: copy half the referenced blocks into a slab
    # extension and remap their ids — scores must not change at all
    uniq = np.unique(np.asarray(ids))
    hot = uniq[: max(1, len(uniq) // 2)]
    slab = jnp.concatenate(
        [bp.msb_plane,
         jnp.zeros((len(hot) * br, d // 2), jnp.uint8)])
    base = n // br
    remap = {int(pb): base + s for s, pb in enumerate(hot)}
    rows_s = (hot[:, None] * br + np.arange(br)).reshape(-1)
    rows_d = np.arange(len(hot) * br) + n
    slab = slab.at[jnp.asarray(rows_d)].set(slab[jnp.asarray(rows_s)])
    sids = jnp.asarray(np.vectorize(lambda x: remap.get(int(x), int(x)))(
        np.asarray(ids)).astype(np.int32))
    got_slab = ops.stage1_scores_gather_resident(q_msb, slab, sids,
                                                 block_rows=br)
    want_slab = ref.stage1_gather_resident_ref(q_eo, slab, sids, br)
    np.testing.assert_array_equal(np.asarray(got_slab),
                                  np.asarray(want_slab))
    np.testing.assert_array_equal(np.asarray(got_slab), np.asarray(got))
    # the engine's lean jnp reference agrees too
    from repro.core.engine import stage1_gather_resident_jnp
    lean = stage1_gather_resident_jnp(q_msb, slab, sids, block_rows=br)
    np.testing.assert_array_equal(np.asarray(lean), np.asarray(got))


def test_stage1_gather_resident_rejects_partial_plane():
    _, bp, q = make_batch(96, 128, 2, seed=3)
    ids = jnp.zeros((2, 2), jnp.int32)
    with pytest.raises(ValueError, match="block multiple"):
        ops.stage1_scores_gather_resident(msb_nibble(q), bp.msb_plane, ids,
                                          block_rows=64)


# ---------------------------------------------------------------------------
# Stage-0 sign-plane kernels (the 1-bit prescreen)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,b,block", [(256, 512, 8, 64), (512, 256, 1, 256),
                                         (96, 128, 32, 32), (250, 512, 4, 64)])
def test_stage0_sign_batched_kernel(n, d, b, block):
    """The 1-bit sign-agreement kernel == oracle == the int8 ground
    truth ``sum_k sign(q_k) sign(d_k)`` recomputed from the raw codes
    (all exact integer arithmetic — bit-for-bit, zero-padded tail
    blocks included via n=250)."""
    db, bp, q = make_batch(n, d, b, seed=n + d + b)
    assert bp.sign_plane is not None
    q_sign = ops.pack_query_signs(q)
    got = ops.stage0_sign_scores_batched(q_sign, bp.sign_plane,
                                         block_n=block)
    want = ref.stage0_sign_batched_ref(q_sign, bp.sign_plane)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # ground truth from the raw int8 codes (0 counts as +1 on both sides)
    sq = np.where(np.asarray(q) < 0, -1, 1).astype(np.int64)
    sd = np.where(np.asarray(db.values) < 0, -1, 1).astype(np.int64)
    np.testing.assert_array_equal(np.asarray(got, np.int64), sq @ sd.T)


@pytest.mark.parametrize("n,d,b,j,br", [(256, 256, 4, 6, 32),
                                        (512, 128, 8, 4, 64),
                                        (250, 512, 2, 8, 32)])
def test_stage0_sign_gather_kernels_two_region_slab(n, d, b, j, br):
    """The stage-0 scalar-prefetch gather (clamped/zero-pad convention,
    n=250 forces a zero-padded tail) and its resident two-region variant:
    slab-region sign blocks mirroring plane blocks score bit-equal to
    the plain-plane gather — region-indifferent like stage 1."""
    _, bp, q = make_batch(n, d, b, seed=n + b)
    q_sign = ops.pack_query_signs(q)
    rng = np.random.default_rng(j)
    nb = -(-n // br)
    ids = jnp.asarray(rng.integers(0, nb, (b, j)).astype(np.int32))
    got = ops.stage0_sign_scores_gather(q_sign, bp.sign_plane, ids,
                                        block_rows=br)
    want = ref.stage0_sign_gather_ref(q_sign, bp.sign_plane, ids, br)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # two-region slab: pad to a block multiple, extend, remap hot blocks
    pad = (-n) % br
    plane = jnp.pad(bp.sign_plane, ((0, pad), (0, 0)))
    uniq = np.unique(np.asarray(ids))
    hot = uniq[: max(1, len(uniq) // 2)]
    slab = jnp.concatenate(
        [plane, jnp.zeros((len(hot) * br, d // 8), jnp.uint8)])
    remap = {int(pb): nb + s for s, pb in enumerate(hot)}
    rows_s = (hot[:, None] * br + np.arange(br)).reshape(-1)
    rows_d = np.arange(len(hot) * br) + nb * br
    slab = slab.at[jnp.asarray(rows_d)].set(slab[jnp.asarray(rows_s)])
    sids = jnp.asarray(np.vectorize(lambda x: remap.get(int(x), int(x)))(
        np.asarray(ids)).astype(np.int32))
    got_slab = ops.stage0_sign_scores_gather_resident(q_sign, slab, sids,
                                                      block_rows=br)
    want_slab = ref.stage0_sign_gather_resident_ref(q_sign, slab, sids, br)
    np.testing.assert_array_equal(np.asarray(got_slab),
                                  np.asarray(want_slab))
    np.testing.assert_array_equal(np.asarray(got_slab), np.asarray(got))
    # the engine's lean jnp backends agree too
    from repro.core.engine import (stage0_sign_gather_batched_jnp,
                                   stage0_sign_gather_resident_jnp)
    lean = stage0_sign_gather_batched_jnp(q_sign, bp.sign_plane, ids,
                                          block_rows=br)
    np.testing.assert_array_equal(np.asarray(lean), np.asarray(got))
    lean_r = stage0_sign_gather_resident_jnp(q_sign, slab, sids,
                                             block_rows=br)
    np.testing.assert_array_equal(np.asarray(lean_r), np.asarray(got))


def test_stage0_sign_plane_matches_msb_derivation():
    """pack_sign_plane(codes) == sign_plane_from_msb(pack_nibble_planes'
    msb): the identity that lets the serving slab derive its combined
    sign plane from the combined msb plane with no second fill path."""
    from repro.core.bitplanar import (pack_nibble_planes, pack_sign_plane,
                                      sign_plane_from_msb)
    rng = np.random.default_rng(29)
    codes = jnp.asarray(rng.integers(-128, 128, (96, 64)).astype(np.int8))
    msb, _ = pack_nibble_planes(codes)
    np.testing.assert_array_equal(np.asarray(pack_sign_plane(codes)),
                                  np.asarray(sign_plane_from_msb(msb)))
