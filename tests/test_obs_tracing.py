"""Tracer semantics + exporter structure (repro.obs.tracing / .export).

Pins: balanced async spans (double-begin / orphan-end raise instead of
silently corrupting the trace), injectable-clock determinism (the same
simulated schedule yields a bit-identical event list), and the exact
structure both exporters emit (Chrome trace_event µs scaling, JSON-lines
record shapes).
"""
import json

import pytest

from repro.obs import (NULL_TRACER, Tracer, chrome_trace,
                       trace_jsonl_records, write_chrome_trace, write_jsonl)


def test_async_span_lifecycle_balanced():
    tr = Tracer()
    tr.begin("request", 1, now=0.0, tid=3)
    tr.begin("request", 2, now=0.5, tid=4)
    assert sorted(tr.open_spans()) == [1, 2]
    tr.end(2, now=1.0)
    tr.end(1, now=2.0, launch=0)
    assert tr.open_spans() == []
    evs = tr.spans("request")
    assert [e.ph for e in evs] == ["B", "B", "E", "E"]
    assert evs[0].tid == 3 and evs[3].attrs == {"launch": 0}


def test_double_begin_and_orphan_end_raise():
    tr = Tracer()
    tr.begin("request", 7, now=0.0)
    with pytest.raises(ValueError):
        tr.begin("request", 7, now=1.0)
    with pytest.raises(KeyError):
        tr.end(8, now=1.0)
    tr.end(7, now=1.0)                     # still closable after the errors
    assert tr.open_spans() == []


def test_sync_span_and_instant():
    tr = Tracer()
    with tr.span("flush", now=2.0, batch=4):
        tr.instant("admit", now=2.0, tid=1, request=0)
    evs = tr.spans()
    # instant recorded inside, the X event appended on exit
    assert [e.ph for e in evs] == ["i", "X"]
    assert evs[1].ts == 2.0 and evs[1].dur == 0.0    # simulated => dur 0
    assert evs[1].attrs == {"batch": 4}


def test_wall_clock_span_measures_duration():
    tr = Tracer()
    with tr.span("work"):
        pass
    (ev,) = tr.spans("work")
    assert ev.ph == "X" and ev.dur >= 0.0


def test_simulated_clock_is_deterministic():
    def drive():
        tr = Tracer()
        t = 0.0
        for i in range(5):
            tr.begin("request", i, now=t, tid=i % 2, request=i)
            t += 0.25
        for i in range(5):
            tr.instant("admit", now=t, request=i)
            tr.end(i, now=t, launch=0)
        return [(e.name, e.ph, e.ts, e.tid, e.dur, tuple(sorted(e.attrs)))
                for e in tr.spans()]

    assert drive() == drive()


def test_chrome_trace_export_structure(tmp_path):
    tr = Tracer()
    tr.begin("request", 0, now=0.001, tid=5, request=0)
    tr.instant("admit", now=0.002, tid=5)
    tr.end(0, now=0.003)
    with tr.span("flush", now=0.003):
        pass
    doc = chrome_trace(tr, pid=2)
    evs = doc["traceEvents"]
    assert [e["ph"] for e in evs] == ["B", "i", "E", "X"]
    assert evs[0]["ts"] == pytest.approx(1000.0)     # seconds -> µs
    assert evs[0]["pid"] == 2 and evs[0]["tid"] == 5
    assert evs[1]["s"] == "t"                        # instant scope
    assert evs[3]["dur"] == 0.0
    path = tmp_path / "trace.json"
    assert write_chrome_trace(str(path), tr, pid=2) == 4
    assert json.loads(path.read_text())["traceEvents"] == doc["traceEvents"]


def test_jsonl_export(tmp_path):
    from repro.obs import MetricsRegistry
    reg = MetricsRegistry()
    reg.counter("hits").inc(2)
    reg.histogram("lat").observe(0.5)
    tr = Tracer()
    tr.instant("tick", now=1.0, step=3)
    path = tmp_path / "events.jsonl"
    n = write_jsonl(str(path), registry=reg, tracer=tr)
    lines = [json.loads(line) for line in path.read_text().splitlines()]
    assert n == len(lines) == 3
    kinds = {(r["type"], r.get("kind", r.get("ph"))) for r in lines}
    assert ("metric", "counter") in kinds and ("event", "i") in kinds
    (ev,) = trace_jsonl_records(tr)
    assert ev["attrs"] == {"step": 3} and ev["ts"] == 1.0


def test_null_tracer_is_inert():
    NULL_TRACER.begin("request", 1, now=0.0)
    NULL_TRACER.end(2)                     # no KeyError: everything no-ops
    NULL_TRACER.instant("x")
    with NULL_TRACER.span("y"):
        pass
    assert NULL_TRACER.open_spans() == [] and NULL_TRACER.spans() == []
    assert len(NULL_TRACER) == 0
    assert chrome_trace(NULL_TRACER)["traceEvents"] == []
