import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import RetrievalConfig, energy
from repro.models import embedder, get_model
from repro.models.common import ModelConfig
from repro.serve import RAGPipeline, generate, jitted_fns, sparse_kv


def tiny_gen():
    cfg = get_config("qwen2-0.5b", smoke=True)
    api = get_model(cfg)
    return api, api.init(jax.random.PRNGKey(0))


def tiny_embedder():
    cfg = embedder.MINILM_CFG.with_(num_layers=2, d_model=32, num_heads=4,
                                    num_kv_heads=4, d_ff=64, vocab_size=128,
                                    pooled_dim=32)
    return cfg, embedder.init_params(cfg, jax.random.PRNGKey(7))


def test_generate_batched():
    api, params = tiny_gen()
    toks = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0, 128)
    out, cache = generate(api, params, {"tokens": toks}, max_new=5)
    assert out.shape == (3, 5)
    # the LAST generated token is sampled but never fed back
    assert int(cache.length[0]) == 8 + 5 - 1


def test_generate_greedy_deterministic():
    api, params = tiny_gen()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128)
    o1, _ = generate(api, params, {"tokens": toks}, max_new=4)
    o2, _ = generate(api, params, {"tokens": toks}, max_new=4)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_rag_pipeline_end_to_end():
    """Offline build + retrieve + augmented generation on tiny models.
    Queries are copies of documents, so retrieval must return the copied
    doc as top-1 (embedder is deterministic)."""
    ecfg, eparams = tiny_embedder()
    api, gparams = tiny_gen()
    rng = np.random.default_rng(3)
    doc_tokens = jnp.asarray(rng.integers(0, 128, (40, 12)).astype(np.int32))
    pipe = RAGPipeline.build(ecfg, eparams, api, gparams, doc_tokens,
                             RetrievalConfig(k=2))
    q = doc_tokens[jnp.asarray([5, 17])]     # queries == docs 5 and 17
    res, ledger = pipe.retrieve(q)
    assert int(np.asarray(res.indices)[0, 0]) == 5
    assert int(np.asarray(res.indices)[1, 0]) == 17
    assert ledger.total_uj > 0
    out, ids, _ = pipe.answer(q, max_new=4)
    assert out.shape == (2, 4)


def test_generate_zero_extra_compiles_on_repeat_calls():
    """generate() must reuse the per-ModelApi cached jits: the second
    call at the same shapes adds ZERO compile-cache entries (pre-fix it
    wrapped api.prefill/api.decode_step in a fresh jax.jit per call,
    recompiling the model every request)."""
    api, params = tiny_gen()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128)
    generate(api, params, {"tokens": toks}, max_new=3)       # warm
    prefill_jit, decode_jit = jitted_fns(api)
    before = (prefill_jit._cache_size(), decode_jit._cache_size())
    o1, _ = generate(api, params, {"tokens": toks}, max_new=3)
    o2, _ = generate(api, params, {"tokens": toks}, max_new=3)
    after = (prefill_jit._cache_size(), decode_jit._cache_size())
    assert after == before, f"recompiled: {before} -> {after}"
    assert jitted_fns(api) == (prefill_jit, decode_jit)      # stable pair
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_rag_pipeline_energy_charges_measured_cascade():
    """RAGPipeline.retrieve must price the launch's measured SchedulePlan
    (stage-1 plane bytes amortized over the query batch), not the
    analytic full-scan cost_hierarchical. Pin the delta: for B > 1 the
    cascade ledger is strictly cheaper than the full-scan charge, and it
    equals cost_cascade of the engine's plain plan exactly."""
    from repro.core import engine as engine_mod
    ecfg, eparams = tiny_embedder()
    api, gparams = tiny_gen()
    rng = np.random.default_rng(3)
    doc_tokens = jnp.asarray(rng.integers(0, 128, (40, 12)).astype(np.int32))
    pipe = RAGPipeline.build(ecfg, eparams, api, gparams, doc_tokens,
                             RetrievalConfig(k=2))
    q = doc_tokens[jnp.asarray([5, 17, 23])]                 # B = 3
    _, ledger = pipe.retrieve(q)
    dim = ecfg.pooled_dim
    plan = engine_mod.plan(pipe.retrieval_cfg, num_docs=40, dim=dim,
                           batch=3, kind="plain")
    want = energy.cost_cascade(plan.stages, dim, batch=plan.batch)
    assert ledger.total_uj == want.total_uj
    full_scan = energy.cost_hierarchical(40, dim)
    assert ledger.total_uj < full_scan.total_uj


def test_sparse_kv_matches_full_attention_when_k_covers_cache():
    from repro.models import attention as A
    b, t, kh, hd, h = 2, 32, 2, 16, 4
    k = jax.random.normal(jax.random.PRNGKey(0), (b, t, kh, hd)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(1), (b, t, kh, hd))
    q = jax.random.normal(jax.random.PRNGKey(2), (b, 1, h, hd))
    length = jnp.full((b,), t, jnp.int32)
    cache = sparse_kv.build_quant_cache(k, v)
    got = sparse_kv.sparse_decode_attention(q, cache, length, top_k=t)
    want = A.decode_attention(q, k, v, length)
    # INT8-quantized keys: small numeric drift allowed
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=0.05)


def test_sparse_kv_topk_approximation_quality():
    """With one dominant key per query, small top-k must recover it.
    (h == kh: the stage-1 selection is per kv-head; grouped queries with
    conflicting relevant tokens are the documented approximation regime.)"""
    b, t, kh, hd, h = 1, 64, 1, 16, 1
    k = jax.random.normal(jax.random.PRNGKey(0), (b, t, kh, hd)) * 0.1
    q = jax.random.normal(jax.random.PRNGKey(2), (b, 1, h, hd))
    # make key 37 align with the query's head-0 direction
    k = k.at[0, 37, 0].set(q[0, 0, 0] * 2.0)
    v = jax.random.normal(jax.random.PRNGKey(1), (b, t, kh, hd))
    length = jnp.full((b,), t, jnp.int32)
    cache = sparse_kv.build_quant_cache(k, v)
    from repro.models import attention as A
    got = sparse_kv.sparse_decode_attention(q, cache, length, top_k=8)
    want = A.decode_attention(q, k, v, length)
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < 0.25


def test_sparse_kv_empty_cache_returns_zeros_not_nan():
    """length == 0: every stage-1 score is NEG_INF-masked, so every
    selected position is invalid. The masked softmax must fall back to a
    zero output — the pre-fix plain softmax over an all-NEG_INF row emits
    NaNs."""
    b, t, kh, hd, h = 2, 16, 2, 16, 4
    k = jax.random.normal(jax.random.PRNGKey(0), (b, t, kh, hd))
    v = jax.random.normal(jax.random.PRNGKey(1), (b, t, kh, hd))
    q = jax.random.normal(jax.random.PRNGKey(2), (b, 1, h, hd))
    cache = sparse_kv.build_quant_cache(k, v)
    out = sparse_kv.sparse_decode_attention(
        q, cache, jnp.zeros((b,), jnp.int32), top_k=8)
    assert out.shape == q.shape
    assert np.array_equal(np.asarray(out, np.float32),
                          np.zeros(q.shape, np.float32))


def test_sparse_kv_short_cache_matches_full_attention():
    """length < top_k: top_k over the masked stage-1 scores necessarily
    selects invalid positions; they must carry zero attention weight, so
    the result equals full attention over the `length` valid positions
    (pre-fix: NaN for the all-invalid rows, polluted weights otherwise)."""
    from repro.models import attention as A
    b, t, kh, hd, h = 2, 32, 2, 16, 4
    k = jax.random.normal(jax.random.PRNGKey(0), (b, t, kh, hd)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(1), (b, t, kh, hd))
    q = jax.random.normal(jax.random.PRNGKey(2), (b, 1, h, hd))
    length = jnp.asarray([3, 5], jnp.int32)       # both < top_k=16
    cache = sparse_kv.build_quant_cache(k, v)
    got = sparse_kv.sparse_decode_attention(q, cache, length, top_k=16)
    want = A.decode_attention(q, k, v, length)
    assert not np.any(np.isnan(np.asarray(got, np.float32)))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=0.05)


def test_sparse_kv_traffic_model():
    dense = sparse_kv.dense_bytes_per_step(32768, 128)
    sparse = sparse_kv.sparse_bytes_per_step(32768, 128, top_k=256)
    assert sparse < dense / 4     # >4x traffic cut at 32k context


def test_quant_decode_matches_dense_decode_with_full_topk():
    """decode_step_quant with top_k >= T must match the bf16 decode path up
    to INT8 key-quantization error (the paper's 'stage-2 == exact' claim,
    transferred to the KV cache)."""
    from repro.models import dense
    from repro.models.common import ModelConfig
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                      attn_chunk=8, compute_dtype="float32", remat=False)
    params = dense.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 97)

    _, cache = dense.prefill(params, toks[:, :8], cfg, max_len=12)
    qcache = dense.init_quant_cache(cfg, 2, 12)
    # prime the quant cache from the bf16 cache
    from repro.serve import sparse_kv
    l, b, t, kh, hd = cache.k.shape
    msb, lsb, scl = jax.vmap(sparse_kv.quantize_keys)(cache.k)
    qcache = dense.QuantCache(k_msb=msb, k_lsb=lsb, k_scale=scl,
                              v=cache.v, length=cache.length)

    lg_d, cache = dense.decode_step(params, cache, toks[:, 8:9], cfg)
    lg_q, qcache = dense.decode_step_quant(params, qcache, toks[:, 8:9],
                                           cfg, top_k=12)
    err = float(jnp.max(jnp.abs(lg_d.astype(jnp.float32)
                                - lg_q.astype(jnp.float32))))
    assert err < 0.1, err
    # a second step keeps agreeing (cache updates are consistent)
    lg_d, cache = dense.decode_step(params, cache, toks[:, 9:10], cfg)
    lg_q, qcache = dense.decode_step_quant(params, qcache, toks[:, 9:10],
                                           cfg, top_k=12)
    err = float(jnp.max(jnp.abs(lg_d.astype(jnp.float32)
                                - lg_q.astype(jnp.float32))))
    assert err < 0.1, err
