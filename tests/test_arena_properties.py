"""Property tests: Arena invariants under random insert/delete/compact.

The arena's contract — stable slot ids, truthful live accounting, owner
map in lockstep with the planes, cluster labels surviving repacks, slot
reuse only after compaction — must hold for EVERY interleaving of online
mutations, not just the sequences the unit tests happen to run. A model
(slot -> expected owner/codes/label) is replayed against the arena and
checked after every operation.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; see requirements.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.tenancy.arena import FREE, Arena, ArenaFull  # noqa: E402

DIM = 16
CAPACITY = 64
NUM_TENANTS = 3

ops = st.lists(
    st.tuples(st.sampled_from(["insert", "delete", "compact"]),
              st.integers(0, NUM_TENANTS - 1),   # tenant
              st.integers(1, 6)),                # rows to insert / delete
    min_size=1, max_size=40)


def make_codes(counter: int, rows: int) -> np.ndarray:
    """Deterministic distinct int8 rows (content-integrity tracers)."""
    base = np.arange(DIM, dtype=np.int64) * 31
    out = [((base + (counter + r) * 17) % 255 - 127) for r in range(rows)]
    return np.asarray(out, np.int8)


def check_model(arena: Arena, model: dict):
    """model: slot -> (tenant, codes row, label)."""
    owner = np.asarray(arena.owner)
    # live-count consistency: the model, the counter, and the owner map
    # must all agree
    assert arena.num_live == len(model) == int((owner >= 0).sum())
    assert 0 <= arena.num_free <= arena.capacity - arena.num_live
    for slot, (tenant, codes, label) in model.items():
        assert owner[slot] == tenant
        assert arena.cluster_labels[slot] == label
    dead = set(range(arena.capacity)) - set(model)
    assert (owner[sorted(dead)] == FREE).all()
    assert (arena.cluster_labels[sorted(dead)] == -1).all()
    if model:
        slots = sorted(model)
        got = np.asarray(arena.read_codes(slots))
        want = np.stack([model[s][1] for s in slots])
        np.testing.assert_array_equal(got, want)


@given(ops)
@settings(max_examples=50, deadline=None)
def test_arena_invariants_under_random_mutation(op_seq):
    arena = Arena(CAPACITY, DIM)
    model: dict[int, tuple] = {}
    counter = 0
    for op, tenant, amount in op_seq:
        if op == "insert":
            codes = make_codes(counter, amount)
            label = tenant % 2            # exercise the label plumbing
            if amount > arena.num_free:
                with pytest.raises(ArenaFull):
                    arena.insert(jnp.asarray(codes), tenant)
            else:
                slots = arena.insert(jnp.asarray(codes), tenant)
                arena.set_labels(slots, [label] * amount)
                # bump allocation: fresh slots, never reused before compact
                assert len(set(slots.tolist())) == amount
                assert not set(slots.tolist()) & set(model)
                for i, s in enumerate(slots):
                    model[int(s)] = (tenant, codes[i], label)
                counter += amount
        elif op == "delete":
            mine = sorted(s for s, (t, _, _) in model.items() if t == tenant)
            victims = mine[:amount]
            before = arena.stats.deletes
            # duplicate ids must be counted once
            arena.delete(victims + victims[:1])
            assert arena.stats.deletes == before + len(victims)
            for s in victims:
                del model[s]
        else:
            mapping = arena.compact()
            # slot reuse: compaction packs live rows to the front and
            # reclaims every tombstone
            assert arena._next == len(model)
            assert arena.num_free == arena.capacity - len(model)
            new_model = {}
            for s, entry in model.items():
                assert mapping[s] >= 0
                new_model[int(mapping[s])] = entry
            assert len(new_model) == len(model)
            # live rows land densely at the slab front, dead slots map to -1
            assert set(new_model) == set(range(len(new_model)))
            assert int((mapping >= 0).sum()) == len(new_model)
            model = new_model
        check_model(arena, model)


@given(ops)
@settings(max_examples=25, deadline=None)
def test_arena_retrieval_only_sees_live_rows(op_seq):
    """After any mutation history, a full masked scan never returns a
    tombstoned or foreign slot (norm-0 + owner masking)."""
    from repro.core import RetrievalConfig
    from repro.core.retrieval import two_stage_retrieve_masked

    arena = Arena(CAPACITY, DIM)
    model = {}
    counter = 0
    for op, tenant, amount in op_seq:
        if op == "insert" and amount <= arena.num_free:
            codes = make_codes(counter, amount)
            for i, s in enumerate(arena.insert(jnp.asarray(codes), tenant)):
                model[int(s)] = (tenant, codes[i])
            counter += amount
        elif op == "delete":
            mine = sorted(s for s, (t, _) in model.items() if t == tenant)
            arena.delete(mine[:amount])
            for s in mine[:amount]:
                del model[s]
        elif op == "compact":
            mapping = arena.compact()
            model = {int(mapping[s]): e for s, e in model.items()}
    q = make_codes(counter + 1000, 1)[0]
    res = two_stage_retrieve_masked(jnp.asarray(q), arena.db(), arena.owner,
                                    jnp.int32(0), RetrievalConfig(k=3))
    got = np.asarray(res.indices)
    for s in got[got >= 0]:
        assert s in model and model[s][0] == 0
