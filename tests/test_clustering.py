"""INT8 k-means codebook + online ClusterIndex maintenance invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitplanar, clustering

DIM = 32


def codes_of(n, seed=0):
    return np.random.default_rng(seed).integers(-128, 128,
                                                (n, DIM)).astype(np.int8)


def test_assign_codes_matches_l2_nearest():
    codes = codes_of(100, seed=1)
    cents = codes_of(7, seed=2)
    labels = clustering.assign_codes(codes, cents)
    d2 = ((codes.astype(np.int64)[:, None, :]
           - cents.astype(np.int64)[None, :, :]) ** 2).sum(-1)
    # same distance minimum; ties may break differently, so compare values
    np.testing.assert_array_equal(d2[np.arange(100), labels], d2.min(axis=1))


def test_kmeans_deterministic_and_consistent():
    codes = codes_of(200, seed=3)
    c1, l1 = clustering.kmeans_int8(codes, 8, iters=4, seed=0)
    c2, l2 = clustering.kmeans_int8(codes, 8, iters=4, seed=0)
    np.testing.assert_array_equal(c1, c2)
    np.testing.assert_array_equal(l1, l2)
    # returned labels are the assignment under the returned centroids
    np.testing.assert_array_equal(l1, clustering.assign_codes(codes, c1))
    assert c1.dtype == np.int8 and l1.min() >= 0 and l1.max() < 8


def test_kmeans_clamps_k_to_rows():
    codes = codes_of(3, seed=4)
    cents, labels = clustering.kmeans_int8(codes, 16, iters=2)
    assert cents.shape == (3, DIM) and len(set(labels.tolist())) <= 3


def test_codebook_is_corpus_representation():
    cents = codes_of(5, seed=5)
    cb = clustering.ClusterCodebook.from_codes(cents)
    msb, _ = bitplanar.pack_nibble_planes(jnp.asarray(cents))
    np.testing.assert_array_equal(np.asarray(cb.msb_plane), np.asarray(msb))
    np.testing.assert_array_equal(
        np.asarray(cb.norms_sq),
        (cents.astype(np.int64) ** 2).sum(-1))
    assert cb.num_clusters == 5 and cb.dim == DIM


def test_block_table_covers_every_row():
    labels = np.asarray([0, 0, 2, 1, 1, 2, 2, 0, -1, 1], np.int32)
    table = clustering.block_table(labels, 3, block_rows=4, pad_pow2=False)
    for row, lab in enumerate(labels):
        if lab >= 0:
            assert row // 4 in table[lab].tolist()
    assert (table >= -1).all()


def test_cluster_grouped_order_groups_labels():
    labels = np.asarray([2, 0, 1, 0, 2, 1, 0], np.int32)
    order = clustering.cluster_grouped_order(labels)
    grouped = labels[order]
    np.testing.assert_array_equal(grouped, np.sort(labels))


class TestClusterIndex:
    def test_first_add_trains_then_assigns(self):
        ci = clustering.ClusterIndex(4, DIM, seed=0)
        assert not ci.trained
        with pytest.raises(RuntimeError):
            ci.codebook()
        l1 = ci.add(codes_of(50, seed=6))
        assert ci.trained and l1.shape == (50,)
        batch = codes_of(10, seed=7)
        l2 = ci.add(batch)
        np.testing.assert_array_equal(
            l2, clustering.assign_codes(batch, ci._centroids))

    def test_sums_counts_track_membership(self):
        ci = clustering.ClusterIndex(4, DIM, seed=0)
        a = codes_of(40, seed=8)
        b = codes_of(12, seed=9)
        la = ci.add(a)
        lb = ci.add(b)
        assert ci._counts.sum() == 52
        ci.remove(b[:5], lb[:5])
        assert ci._counts.sum() == 47
        all_codes = np.concatenate([a, b[5:]])
        all_labels = np.concatenate([la, lb[5:]])
        for c in range(4):
            members = all_codes[all_labels == c].astype(np.float64)
            np.testing.assert_allclose(ci._sums[c],
                                       members.sum(axis=0), atol=1e-9)
            assert ci._counts[c] == len(members)

    def test_refresh_recomputes_centroids_from_sums(self):
        ci = clustering.ClusterIndex(2, DIM, seed=1)
        codes = codes_of(30, seed=10)
        labels = ci.add(codes)
        gen = ci.generation
        ci.refresh()
        for c in range(2):
            members = codes[labels == c].astype(np.float64)
            if len(members):
                want = np.clip(np.rint(members.mean(axis=0)),
                               -128, 127).astype(np.int8)
                np.testing.assert_array_equal(ci._centroids[c], want)
        # refresh with unchanged sums afterwards must not bump generation
        gen2 = ci.generation
        ci.refresh()
        assert ci.generation == gen2
        assert gen2 >= gen

    def test_codebook_cached_per_generation(self):
        ci = clustering.ClusterIndex(2, DIM, seed=2)
        ci.add(codes_of(20, seed=11))
        cb1 = ci.codebook()
        assert ci.codebook() is cb1
        ci.add(codes_of(200, seed=12))
        ci.refresh()                     # centroids move -> new generation
        assert ci.codebook() is not cb1
