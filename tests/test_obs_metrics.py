"""Properties of the metrics substrate (repro.obs.metrics).

The load-bearing claims, each pinned here:

  * every reported percentile of a log-bucketed histogram is within the
    DOCUMENTED relative-error bound of the exact order statistic, for
    arbitrary value distributions (mixed scales, zeros, near-boundary
    values — fuzzed by hypothesis where available, swept
    deterministically always);
  * merging registries is associative/commutative for every percentile
    (integer bucket counts — merge order can never change a quantile);
  * the Prometheus text export round-trips through the validating parser
    with cumulative bucket counts intact;
  * the NullRegistry exposes the full API as no-ops.
"""
import math

import numpy as np
import pytest

from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       NULL_REGISTRY, parse_prometheus, prometheus_text)

# The hypothesis-based properties skip (not fail) where hypothesis is
# absent — mirroring test_runtime_properties — but the deterministic
# tests below always run.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


def exact_percentile(values, q):
    """The rank-ceil(q/100*n) order statistic (the histogram's target)."""
    vals = sorted(values)
    rank = max(1, math.ceil(q / 100.0 * len(vals)))
    return vals[rank - 1]


def _assert_percentile_bound(vals, q):
    h = Histogram("t")
    for v in vals:
        h.observe(v)
    got = h.percentile(q)
    want = exact_percentile(vals, q)
    if want <= 0.0:
        assert got == 0.0                 # zero bucket is exact
    else:
        # small slack: float log2 at an exact bucket edge may land the
        # observation one bucket over, which still satisfies the bound
        # up to fp rounding of the edge itself.
        bound = h.rel_error_bound * 1.0001 + 1e-12
        assert abs(got - want) <= bound * want, (got, want, q)


def _merge_three_ways(a, b, c):
    def reg(vals):
        r = MetricsRegistry()
        hist = r.histogram("lat")
        for v in vals:
            hist.observe(v)
        r.counter("n").inc(len(vals))
        return r

    left = reg(a).merge(reg(b)).merge(reg(c))      # (a + b) + c
    right = reg(c).merge(reg(b)).merge(reg(a))     # c + (b + a)
    hl = left.get("histogram", "lat")
    hr = right.get("histogram", "lat")
    assert hl.buckets == hr.buckets and hl.count == hr.count
    assert hl.zero_count == hr.zero_count
    for q in (1, 50, 95, 99, 100):
        assert hl.percentile(q) == hr.percentile(q)
    # float totals are only approximately order-independent
    assert hl.total == pytest.approx(hr.total, rel=1e-9, abs=1e-12)
    assert left.get("counter", "n").value == right.get("counter", "n").value


if HAVE_HYPOTHESIS:
    # Mixed magnitudes spanning ~12 decades plus exact zeros: the bound
    # must hold with no a-priori value range.
    observations = st.lists(
        st.one_of(st.floats(1e-9, 1e3), st.just(0.0),
                  st.floats(0.999, 1.001)),   # near a bucket boundary
        min_size=1, max_size=200)

    @settings(max_examples=200, deadline=None)
    @given(vals=observations,
           q=st.sampled_from([1, 25, 50, 90, 95, 99, 100]))
    def test_percentile_within_documented_relative_error(vals, q):
        _assert_percentile_bound(vals, q)

    @settings(max_examples=100, deadline=None)
    @given(a=observations, b=observations, c=observations)
    def test_registry_merge_is_associative_for_percentiles(a, b, c):
        _merge_three_ways(a, b, c)


def test_percentile_bound_on_random_distributions():
    """Deterministic sweep of the same property the hypothesis test
    fuzzes: uniform/lognormal/zero-heavy samples at many sizes."""
    rng = np.random.default_rng(0)
    cases = []
    for n in (1, 2, 3, 17, 100, 999):
        cases.append(rng.uniform(1e-6, 1e3, n))
        cases.append(rng.lognormal(0.0, 2.0, n))
        cases.append(np.concatenate([np.zeros(n // 2 + 1),
                                     rng.uniform(0.5, 2.0, n)]))
    for vals in cases:
        for q in (1, 25, 50, 90, 95, 99, 100):
            _assert_percentile_bound([float(v) for v in vals], q)


def test_merge_associativity_deterministic():
    rng = np.random.default_rng(1)
    a = [float(v) for v in rng.lognormal(0, 3, 50)]
    b = [0.0] + [float(v) for v in rng.uniform(1e-7, 1e4, 80)]
    c = [float(v) for v in rng.normal(5, 1, 30).clip(min=0)]
    _merge_three_ways(a, b, c)


def test_histogram_weighted_observe_equals_repeats():
    h1, h2 = Histogram("a"), Histogram("b")
    for _ in range(7):
        h1.observe(3.5)
    h2.observe(3.5, 7)
    assert h1.buckets == h2.buckets and h1.count == h2.count == 7
    assert h1.total == pytest.approx(h2.total)
    with pytest.raises(ValueError):
        h2.observe(1.0, 0)


def test_percentiles_against_numpy_on_lognormal():
    rng = np.random.default_rng(3)
    vals = rng.lognormal(mean=-2.0, sigma=1.5, size=5000)
    h = Histogram("lat")
    for v in vals:
        h.observe(float(v))
    for q in (50, 95, 99):
        want = exact_percentile(vals, q)
        assert abs(h.percentile(q) - want) <= h.rel_error_bound * want * 1.001


def test_counter_gauge_and_registry_basics():
    r = MetricsRegistry()
    c = r.counter("req", path="warm")
    c.inc()
    c.inc(4)
    assert c.value == 5
    assert r.counter("req", path="warm") is c          # get-or-create
    assert r.counter("req", path="cold") is not c      # labels distinguish
    with pytest.raises(ValueError):
        c.inc(-1)
    g = r.gauge("depth")
    g.set(7.0)
    assert r.get("gauge", "depth").value == 7.0
    assert r.get("counter", "missing") is None
    snap = r.snapshot()
    assert snap["counters"]["req{path=warm}"] == 5
    r.reset()
    assert c.value == 0 and g.value == 0.0
    assert isinstance(c, Counter) and isinstance(g, Gauge)


def test_histogram_empty_and_zero_behaviour():
    h = Histogram("t")
    assert math.isnan(h.percentile(50))
    h.observe(0.0)
    h.observe(-1.0)                       # clamped into the zero bucket
    assert h.percentile(99) == 0.0 and h.count == 2
    with pytest.raises(ValueError):
        h.observe(float("nan"))
    with pytest.raises(ValueError):
        h.percentile(101)


def test_prometheus_roundtrip_cumulative_buckets():
    r = MetricsRegistry()
    r.counter("hits", tier="l1").inc(3)
    r.gauge("depth").set(2.5)
    h = r.histogram("lat", path="warm")
    for v in (0.0, 0.001, 0.002, 0.002, 5.0):
        h.observe(v)
    text = prometheus_text(r)
    parsed = parse_prometheus(text)
    assert parsed["hits"] == [({"tier": "l1"}, 3.0)]
    assert parsed["depth"] == [({}, 2.5)]
    buckets = parsed["lat_bucket"]
    # cumulative and capped by +Inf == count
    counts = [v for _, v in buckets]
    assert counts == sorted(counts)
    assert buckets[-1][0]["le"] == "+Inf" and buckets[-1][1] == 5.0
    assert parsed["lat_count"] == [({"path": "warm"}, 5.0)]
    assert parsed["lat_sum"][0][1] == pytest.approx(5.005)
    # the zero bucket exports as le="0"
    assert buckets[0][0]["le"] == "0" and buckets[0][1] == 1.0
    with pytest.raises(ValueError):
        parse_prometheus("not a metric line at all!")


def test_null_registry_is_inert():
    n = NULL_REGISTRY
    assert not n.enabled
    c = n.counter("x")
    c.inc(5)
    n.histogram("h").observe(1.0, 3)
    n.gauge("g").set(2.0)
    assert c.value == 0 and n.metrics() == [] and n.get("counter", "x") is None
    assert n.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}
    assert n.merge(MetricsRegistry()) is n
    n.reset()
