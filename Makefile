# Tier-1 verify + benchmark entry points. PYTHONPATH is set per-target so
# `make test` matches the ROADMAP.md command exactly.
PY ?= python

.PHONY: test test-fast lint bench-smoke bench example trace

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# ruff (config in pyproject.toml) + guard against committed bytecode
lint:
	ruff check src tests benchmarks examples
	@if git ls-files | grep -E '(\.pyc$$|__pycache__)'; then \
		echo "ERROR: tracked bytecode files (see above)"; exit 1; \
	else echo "no tracked bytecode"; fi

test-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow"

# quick structural checks: tenancy arena + batched-kernel parity/traffic
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.tenancy_bench --smoke
	PYTHONPATH=src $(PY) -m benchmarks.retrieval_bench --smoke

# the full paper-table benchmark sweep
bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

example:
	PYTHONPATH=src $(PY) examples/multi_user_agent.py

trace:
	PYTHONPATH=src $(PY) -m repro.launch.serve_tenants --tenants 6 \
		--capacity 512 --steps 30 --clusters 8 --cache-kb 256
