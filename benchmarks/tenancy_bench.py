"""Multi-tenant arena benchmark: batched cross-tenant serving + online ingest.

Two claims measured (CPU wall-clock is dispatch-dominated here, which is
exactly the effect batching removes; on TPU the batched path additionally
amortizes the HBM stream of the MSB plane across the whole batch):

  1. QUERIES: one vmapped segment-masked two-stage retrieval over the
     shared arena vs. the naive baseline — a sequential loop of
     two_stage_retrieve calls, one per tenant over that tenant's own
     BitPlanarDB. Acceptance: >= 5x queries/sec at B=16 tenants.
  2. INGEST: streaming 1k docs into the arena (quantize + pack into free
     slots, O(rows) per chunk) vs. the seed's only alternative — rebuild
     the tenant's database from scratch on every chunk. The arena path
     must issue ZERO rebuilds (arena.stats.rebuilds == 0 by construction).

    PYTHONPATH=src python -m benchmarks.tenancy_bench [--smoke]
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
import numpy as np                                             # noqa: E402

from repro.core import (BitPlanarDB, QuantizedDB,              # noqa: E402
                        RetrievalConfig, build_database,
                        quantize_int8, two_stage_retrieve)
from repro.data import retrieval_corpus                        # noqa: E402
from repro.tenancy import MultiTenantIndex                     # noqa: E402


def _compare(fn_a, fn_b, rounds=12, reps_a=3, reps_b=10):
    """Paired comparison robust to machine-speed drift: each round times
    both paths back-to-back (same machine state), and the reported
    speedup is the MEDIAN of per-round ratios — a slow round slows both
    sides and leaves its ratio intact, unlike timing the two paths in
    separate windows. Returns (t_a, t_b, speedup=median(a/b))."""
    fn_a(), fn_b()                         # warm both outside the clock
    ratios, ts_a, ts_b = [], [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(reps_a):
            out = fn_a()
        jax.block_until_ready(out)
        ta = (time.perf_counter() - t0) / reps_a
        t0 = time.perf_counter()
        for _ in range(reps_b):
            out = fn_b()
        jax.block_until_ready(out)
        tb = (time.perf_counter() - t0) / reps_b
        ratios.append(ta / tb)
        ts_a.append(ta)
        ts_b.append(tb)
    ratios.sort()
    return (sorted(ts_a)[len(ts_a) // 2], sorted(ts_b)[len(ts_b) // 2],
            ratios[len(ratios) // 2])


def _per_tenant_db(codes: jnp.ndarray, scale) -> BitPlanarDB:
    """A standalone BitPlanarDB over one tenant's fixed-scale codes."""
    norms = jnp.sum(codes.astype(jnp.int32) ** 2, axis=-1)
    return BitPlanarDB.from_quantized(
        QuantizedDB(values=codes, scale=jnp.float32(scale), norms_sq=norms))


def bench_queries(num_tenants: int, docs_per_tenant: int, dim: int,
                  cfg: RetrievalConfig):
    """Batched cross-tenant vs sequential per-tenant retrieval."""
    index = MultiTenantIndex(num_tenants * docs_per_tenant, dim, cfg)
    dbs, queries, slot0 = [], [], []
    for t in range(num_tenants):
        docs, qs, gold = retrieval_corpus(docs_per_tenant, dim,
                                          num_queries=1, seed=t, noise=0.08)
        codes = index.arena.quantize(jnp.asarray(docs))
        slots = index.ingest_codes(t, codes)
        dbs.append(_per_tenant_db(codes, index.arena.scale))
        qc, _ = quantize_int8(jnp.asarray(qs[0]))
        queries.append(np.asarray(qc))
        slot0.append(int(slots[0]))

    tids = np.arange(num_tenants, dtype=np.int32)   # host-side on purpose

    # Both paths receive HOST-side query codes (as a server does) and pay
    # their own host->device transfers: one for the batch, B for the loop.
    def sequential():
        res = [two_stage_retrieve(jnp.asarray(queries[t]), dbs[t], cfg)
               for t in range(num_tenants)]
        return res[-1].indices

    def batched():
        return index.retrieve(jnp.asarray(np.stack(queries)), tids).indices

    t_seq, t_bat, speedup = _compare(sequential, batched)

    # isolation sanity on the measured path: every valid hit is the caller's
    res = index.retrieve(jnp.asarray(np.stack(queries)), tids)
    owner = np.asarray(index.arena.owner)
    idx = np.asarray(res.indices)
    isolated = all(owner[i] == t for t, row in enumerate(idx)
                   for i in row if i >= 0)
    # the batched path agrees with per-tenant top-1 (slot offset removed)
    seq_top1 = [int(np.asarray(two_stage_retrieve(
        jnp.asarray(queries[t]), dbs[t], cfg).indices)[0])
        for t in range(num_tenants)]
    agree = all(idx[t, 0] - slot0[t] == seq_top1[t]
                for t in range(num_tenants))
    return {
        "seq_ms": t_seq * 1e3, "batched_ms": t_bat * 1e3,
        "seq_qps": num_tenants / t_seq, "batched_qps": num_tenants / t_bat,
        "speedup": speedup, "isolated": isolated, "agree": agree,
    }


def bench_ingest(total_docs: int, chunk: int, dim: int):
    """Streaming arena ingest vs naive rebuild-per-chunk."""
    docs, _, _ = retrieval_corpus(total_docs, dim, num_queries=1, seed=9)
    docs = jnp.asarray(docs)
    chunks = [docs[i:i + chunk] for i in range(0, total_docs, chunk)]

    index = MultiTenantIndex(total_docs, dim)
    t0 = time.perf_counter()
    for c in chunks:
        index.ingest(0, c)
    jax.block_until_ready(index.arena.msb_plane)
    t_online = time.perf_counter() - t0

    t0 = time.perf_counter()
    for i in range(1, len(chunks) + 1):
        # the seed's only path: re-embedless rebuild of EVERYTHING so far
        db = build_database(jnp.concatenate(chunks[:i], axis=0))
        bp = BitPlanarDB.from_quantized(db)
    jax.block_until_ready(bp.msb_plane)
    t_rebuild = time.perf_counter() - t0

    return {
        "online_s": t_online, "rebuild_s": t_rebuild,
        "online_rows_per_s": total_docs / t_online,
        "rebuild_rows_per_s": total_docs / t_rebuild,
        "rebuilds_issued": index.arena.stats.rebuilds,
        "inserted": index.num_live,
    }


# Wall-clock gate; on --smoke (CI on shared runners) it is reported but
# excluded from the exit code — structural checks always gate.
TIMING_CHECK = "batched >= 5x sequential queries/sec at B=16"


def run(verbose=True, smoke=False):
    # The wearable operating point: each user carries a PERSONAL corpus of
    # tens of records (EdgeRAG regime), so serving B users sequentially is
    # dispatch-bound — exactly what cross-tenant batching removes.
    b = 16
    n_per = 32
    dim = 128 if smoke else 512
    # max_candidates=10 is the small-corpus operating point (the paper's
    # frac-0.2 rule gives 7 for 32 docs anyway); it applies to BOTH paths,
    # keeping the arena's stage-2 budget comparable to the per-tenant DBs'.
    cfg = RetrievalConfig(k=5, metric="cosine", max_candidates=10)
    q = bench_queries(b, n_per, dim, cfg)
    ing = bench_ingest(256 if smoke else 1024, 64, dim)

    if verbose:
        print(f"== cross-tenant serving (B={b} tenants x {n_per} docs, "
              f"D={dim}) ==")
        print(f"  sequential per-tenant loop: {q['seq_ms']:8.2f} ms/batch "
              f"({q['seq_qps']:8.1f} q/s)")
        print(f"  batched shared arena:       {q['batched_ms']:8.2f} ms/batch "
              f"({q['batched_qps']:8.1f} q/s)")
        print(f"  speedup: {q['speedup']:.1f}x   isolation: {q['isolated']}   "
              f"top-1 agreement: {q['agree']}")
        print(f"== online ingest ({ing['inserted']} docs, chunk=64, "
              f"D={dim}) ==")
        print(f"  arena online insert: {ing['online_s']:6.2f} s "
              f"({ing['online_rows_per_s']:8.0f} rows/s), "
              f"rebuilds issued: {ing['rebuilds_issued']}")
        print(f"  naive rebuild/chunk: {ing['rebuild_s']:6.2f} s "
              f"({ing['rebuild_rows_per_s']:8.0f} rows/s)")

    checks = {
        TIMING_CHECK:
            q["speedup"] >= 5.0,
        "batched results match per-tenant retrieval":
            q["agree"] and q["isolated"],
        "1k-doc online ingest issued zero rebuilds":
            ing["rebuilds_issued"] == 0 and ing["inserted"] >= (
                256 if smoke else 1024),
        "online ingest beats naive rebuild-per-chunk":
            ing["online_s"] < ing["rebuild_s"],
    }
    records = {
        f"cross_tenant_B{b}": {"median_ms": q["batched_ms"],
                               "ref_median_ms": q["seq_ms"],
                               "ratio": q["speedup"]},
        "online_ingest": {"median_ms": ing["online_s"] * 1e3,
                          "ref_median_ms": ing["rebuild_s"] * 1e3,
                          "ratio": ing["rebuild_s"] / ing["online_s"]},
    }
    return {"queries": q, "ingest": ing, "checks": checks,
            "records": records}


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    out = run(verbose=True, smoke=smoke)
    print(out["checks"])
    gating = {k: v for k, v in out["checks"].items()
              if not (smoke and k == TIMING_CHECK)}
    sys.exit(0 if all(gating.values()) else 1)
