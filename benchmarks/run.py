"""Benchmark orchestrator: one module per paper table/figure + roofline.

Also emits BENCH_retrieval.json — a machine-readable record of every
timed benchmark (median ms + ratio vs its reference path) so the perf
trajectory is tracked across PRs instead of living in scrollback.

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import json
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import (fig4_reduction, fig5_energy, kernel_bench,  # noqa: E402
                        retrieval_bench, table1_precision, table2_energy,
                        table3_comparison, tenancy_bench)

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_retrieval.json")


def main() -> int:
    modules = [
        ("Fig. 4  (memory/compute reduction)", fig4_reduction),
        ("Table I (retrieval precision protocol)", table1_precision),
        ("Table II (module energy)", table2_energy),
        ("Fig. 5  (energy per query by format)", fig5_energy),
        ("Table III (accelerator comparison)", table3_comparison),
        ("Kernel microbench", kernel_bench),
        ("Batched retrieval engine (batched vs vmapped-scalar)",
         retrieval_bench),
        ("Multi-tenant arena (batched serving + online ingest)",
         tenancy_bench),
    ]
    failures = []
    records: dict[str, dict] = {}
    for name, mod in modules:
        print("\n" + "=" * 72)
        print(name)
        print("=" * 72)
        try:
            out = mod.run(verbose=True)
            if out.get("records"):
                records[mod.__name__.split(".")[-1]] = out["records"]
            for check, ok in out["checks"].items():
                print(f"  [{'PASS' if ok else 'FAIL'}] {check}")
                if not ok:
                    failures.append(f"{name}: {check}")
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures.append(f"{name}: exception")

    with open(BENCH_JSON, "w") as f:
        json.dump(records, f, indent=2, sort_keys=True)
    print(f"\nwrote {os.path.normpath(BENCH_JSON)} "
          f"({sum(len(v) for v in records.values())} benchmark records)")

    # roofline table (requires results/dryrun.json from the dry-run)
    print("\n" + "=" * 72)
    print("Roofline (from dry-run artifacts)")
    print("=" * 72)
    try:
        from benchmarks import roofline
        if os.path.exists(roofline.RESULTS):
            roofline.run(verbose=True)
        else:
            print("  (results/dryrun.json not found — run "
                  "`python -m repro.launch.dryrun --all --mesh both` first)")
    except Exception:  # noqa: BLE001
        traceback.print_exc()
        failures.append("roofline: exception")

    print("\n" + "=" * 72)
    if failures:
        print(f"{len(failures)} benchmark check(s) FAILED:")
        for f in failures:
            print("  -", f)
        return 1
    print("ALL BENCHMARK CHECKS PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
