"""Paper Table III: accelerator comparison (energy/query on SciFact).

The RTX3090 / Chameleon rows are quoted from the paper (we cannot measure
them); 'this work' is our cost-model reproduction of the paper's
accelerator, plus the TPU-v5e-equivalent accounting of the SAME
hierarchical scheme from this framework (per-chip share of a sharded
corpus, DESIGN.md §2)."""
from repro.core import energy as en

SCIFACT_DOCS = 4020     # corpus size implied by the paper's 337.74 uJ


def run(verbose=True):
    ours = en.cost_hierarchical(SCIFACT_DOCS)
    int8 = en.cost_int8(SCIFACT_DOCS)
    rows = [
        {"work": "RTX3090 (paper-quoted)", "tech": "8nm",
         "energy_uJ": 86_800.0, "P@1": 0.507},
        {"work": "Chameleon 1FPGA+2GPU (paper-quoted)", "tech": "16+8nm",
         "energy_uJ": 95_600.0, "P@1": None},
        {"work": "Paper accelerator (reported)", "tech": "TSMC 28nm",
         "energy_uJ": 337.74, "P@1": 0.497},
        {"work": "This repro (cost model, hier)", "tech": "TSMC 28nm",
         "energy_uJ": ours.total_uj, "P@1": None},
        {"work": "This repro (cost model, pure INT8)", "tech": "TSMC 28nm",
         "energy_uJ": int8.total_uj, "P@1": None},
    ]
    if verbose:
        print("== Table III: energy/query on SciFact-sized corpus ==")
        for r in rows:
            p = f"{r['P@1']:.3f}" if r["P@1"] else "   - "
            print(f"{r['work']:>38} {r['tech']:>10} "
                  f"{r['energy_uJ']:>12.2f} uJ  P@1={p}")
        speedup = 86_800.0 / ours.total_uj
        print(f"-> reproduced accelerator vs GPU: {speedup:.0f}x lower "
              "energy (paper claims ~2 orders of magnitude)")
    checks = {
        "repro matches paper's 337.74uJ (<5%)":
            abs(ours.total_uj - 337.74) / 337.74 < 0.05,
        ">=2 orders of magnitude vs RTX3090":
            86_800.0 / ours.total_uj >= 100,
        "hier beats pure INT8": ours.total_uj < int8.total_uj,
    }
    return {"rows": rows, "checks": checks}


if __name__ == "__main__":
    print(run()["checks"])
