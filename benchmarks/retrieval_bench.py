"""Batched retrieval engine benchmark: batched kernels vs the vmapped-scalar path.

Two currencies, per the paper:

  1. BYTES STREAMED (exact, analytic — engine.plan): the batched stage-1
     matmul kernel fetches each doc-plane block from HBM once per BATCH
     (N * D/2 bytes regardless of B); the old vmapped-scalar path fetched
     it once per QUERY (B * N * D/2). Computed, not timed — this is the
     paper's memory-access argument applied to batch serving.
  2. WALL-CLOCK at B in {8, 32, 128}: the batched kernel vs vmapping the
     single-query kernel over the batch, plus the batched jnp engine body
     vs a per-query loop. On CPU, Pallas runs in interpret mode, so kernel
     times are RELATIVE indicators (the batched win is structural: one
     grid sweep instead of B); jnp times are real wall-clock.

Parity is asserted bit-for-bit on every shape before anything is timed —
a kernel-path regression fails the checks instead of silently degrading.

    PYTHONPATH=src python -m benchmarks.retrieval_bench [--smoke]
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
import numpy as np                                             # noqa: E402

from benchmarks._timing import median_ms as _median_ms         # noqa: E402
from repro.core import (BitPlanarDB, RetrievalConfig,          # noqa: E402
                        RetrievalEngine, build_database,
                        quantize_int8)
from repro.core.quantization import msb_nibble                 # noqa: E402
from repro.kernels import ops                                  # noqa: E402

# Wall-clock checks are excluded from the exit code in --smoke mode
# (tiny shapes on shared CI runners); the structural parity + byte-model
# checks always gate.
TIMING_CHECK = "batched stage-1 kernel faster than vmapped-scalar at B=32"


def _build(n, d, bmax, seed=0):
    rng = np.random.default_rng(seed)
    db = build_database(jnp.asarray(
        rng.normal(size=(n, d)).astype(np.float32)))
    bp = BitPlanarDB.from_quantized(db)
    q, _ = quantize_int8(jnp.asarray(
        rng.normal(size=(bmax, d)).astype(np.float32)), per_vector=True)
    return bp, q


def run(verbose=True, smoke=False):
    n, d = (512, 128) if smoke else (4096, 512)
    batches = (4,) if smoke else (8, 32, 128)
    reps = 3 if smoke else 5
    cfg = RetrievalConfig(k=5, metric="cosine")
    eng = RetrievalEngine(cfg)
    bp, q_all = _build(n, d, max(batches))
    plane_bytes = n * (d // 2)

    vmapped_stage1 = jax.jit(jax.vmap(
        lambda qm: ops.stage1_scores(qm, bp.msb_plane)))

    records: dict[str, dict] = {}
    parity_ok, plan_ok = True, True
    for b in batches:
        q = q_all[:b]
        q_msb = msb_nibble(q)

        # ---- parity first: the batched kernel must equal the vmapped
        # scalar kernel bit-for-bit (both exact integer arithmetic).
        got = ops.stage1_scores_batched(q_msb, bp.msb_plane)
        want = vmapped_stage1(q_msb)
        parity_ok &= bool(jnp.array_equal(got, want))

        # ---- analytic bytes (exact): once per batch vs once per query.
        plan = eng.plan_for(bp, b)
        plan_ok &= (plan.stage1_bytes == plane_bytes
                    and plan.stage1_bytes_vmapped == b * plane_bytes)

        # ---- wall-clock: kernels (interpret on CPU) and jnp engine body.
        t_batched = _median_ms(ops.stage1_scores_batched, q_msb,
                               bp.msb_plane, reps=reps)
        t_vmapped = _median_ms(vmapped_stage1, q_msb, reps=reps)
        records[f"stage1_kernel_B{b}"] = {
            "median_ms": t_batched, "ref_median_ms": t_vmapped,
            "ratio": t_vmapped / t_batched,
            "bytes_streamed": plan.stage1_bytes,
            "bytes_streamed_vmapped": plan.stage1_bytes_vmapped,
        }

        batched_engine = lambda qq: eng.retrieve(qq, bp)
        per_query = lambda qq: [eng.retrieve_single(qq[i], bp)
                                for i in range(qq.shape[0])]
        t_eng = _median_ms(batched_engine, q, reps=reps)
        t_loop = _median_ms(per_query, q, reps=reps)
        records[f"two_stage_jnp_B{b}"] = {
            "median_ms": t_eng, "ref_median_ms": t_loop,
            "ratio": t_loop / t_eng,
        }

    if verbose:
        mode = ("smoke shapes, CPU interpret" if smoke else
                "CPU: Pallas interpret mode — kernel times are relative "
                "indicators; bytes are exact")
        print(f"== batched engine vs vmapped-scalar path "
              f"(N={n} D={d}; {mode}) ==")
        for name, r in records.items():
            line = (f"  {name:>22}: {r['median_ms']:9.2f} ms   "
                    f"ref {r['ref_median_ms']:9.2f} ms   "
                    f"speedup {r['ratio']:6.2f}x")
            if "bytes_streamed" in r:
                line += (f"   bytes {r['bytes_streamed']:>12,} vs "
                         f"{r['bytes_streamed_vmapped']:>14,}")
            print(line)
        print(f"  doc plane per batched launch: {plane_bytes:,} bytes "
              f"(= N*D/2, streamed ONCE per batch)")

    mid = f"stage1_kernel_B{32 if not smoke else batches[0]}"
    checks = {
        "batched kernel == vmapped kernel bit-for-bit (all B)": parity_ok,
        "doc plane streamed exactly once per batch (analytic)": plan_ok,
        TIMING_CHECK: records[mid]["ratio"] > 1.0,
    }
    return {"records": records, "checks": checks}


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    out = run(verbose=True, smoke=smoke)
    print(out["checks"])
    gating = {k: v for k, v in out["checks"].items()
              if not (smoke and k == TIMING_CHECK)}
    sys.exit(0 if all(gating.values()) else 1)
