"""Batched retrieval engine benchmark: batched kernels vs the vmapped-scalar path,
the cluster-pruned cascade vs the full two-stage scan, and the serving
runtime's hot-cluster cache on a correlated session trace.

Three currencies, per the paper:

  1. BYTES STREAMED (exact, analytic — engine.plan): the batched stage-1
     matmul kernel fetches each doc-plane block from HBM once per BATCH
     (N * D/2 bytes regardless of B); the old vmapped-scalar path fetched
     it once per QUERY (B * N * D/2). The cluster-pruned cascade drops
     stage-1 to each lane's probed blocks (~N * nprobe / K rows) after a
     K-row centroid pass. Computed, not timed — the paper's memory-access
     argument applied to batch serving and then to arena growth.
  2. WALL-CLOCK at B in {8, 32, 128}: the batched kernel vs vmapping the
     single-query kernel over the batch, plus the batched jnp engine body
     vs a per-query loop, plus the cascade body vs the full scan. On CPU,
     Pallas runs in interpret mode, so kernel times are RELATIVE
     indicators (the batched win is structural: one grid sweep instead of
     B); jnp times are real wall-clock.
  3. RECALL@k of the cascade vs the full two-stage scan on a synthetic
     clustered corpus (64k docs in the full run) — the prune must buy its
     byte reduction without giving up the paper's retrieval quality
     (gate: >= 0.95).

A fourth section drives the SERVING RUNTIME (repro.serve.runtime) over a
correlated multi-tenant session trace (8 tenants, Zipf cluster
popularity, sticky per-session focus): the same trace runs cold
(hot-cluster cache disabled — every flush streams its probed blocks from
HBM, the pre-cache serving path) and warm (device-resident packed slab
cache, preloaded). Both are timed as LONG-LIVED session servers and the
gate compares steady-state per-turn MEDIANS (the warm first pass — slab
allocation + every fill — is recorded separately). Gates: the warm
runtime must stream >= 2x fewer stage-1 HBM bytes per query, return
BIT-IDENTICAL results to the cold run, match sequential per-request
retrieval — so the cache can only ever change where bytes come from,
never what is retrieved — AND must not be slower than the cold cascade
in wall-clock (warm >= cold on full runs, a relaxed bound in smoke).
The wall-clock gate always participates in the exit code: a warm path
that wins the bytes ledger while losing latency is a regression, not a
win.

Parity is asserted bit-for-bit on every shape before anything is timed —
a kernel-path regression fails the checks instead of silently degrading.

    PYTHONPATH=src python -m benchmarks.retrieval_bench [--smoke]
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
import numpy as np                                             # noqa: E402

from benchmarks._timing import median_ms as _median_ms         # noqa: E402
from repro.core import (BitPlanarDB, RetrievalConfig,          # noqa: E402
                        RetrievalEngine, build_database, clustering,
                        quantize_int8)
from repro.core.quantization import msb_nibble                 # noqa: E402
from repro.core.retrieval import (batched_retrieve,            # noqa: E402
                                  cluster_pruned_retrieve)
from repro.data import retrieval_corpus                        # noqa: E402
from repro.kernels import ops                                  # noqa: E402

# Wall-clock checks are excluded from the exit code in --smoke mode
# (tiny shapes on shared CI runners); the structural parity + byte-model
# checks always gate.
TIMING_CHECK = "batched stage-1 kernel faster than vmapped-scalar at B=32"
# The serving runtime's warm-vs-cold wall-clock gate ALWAYS gates (this
# is exactly the regression class that shipped a 0.43x warm path while
# only bytes/parity/recall were checked): full runs demand warm >= cold;
# smoke runs keep a relaxed bound (tiny shapes on shared runners are
# python-overhead-dominated and noisy, but a 2x-slower warm path still
# fails).
SERVING_TIMING_CHECK = "serving runtime: warm wall-clock >= cold cascade"
SERVING_SMOKE_BOUND = 0.5
# The >= 4x stage-1 byte reduction needs arena >> batch * probe; at smoke
# shapes the per-lane gathers don't amortize, so the gate is full-run only
# (the byte MODEL itself — plan == analytic formula — always gates).
BYTES_CHECK = "cascade stage-1 bytes >= 4x below the full scan (analytic)"
# The observability layer's overhead contract: serving the SAME warm
# trace through a real MetricsRegistry + Tracer must stay within 2% of
# the NullRegistry path on the per-turn MEDIAN. Full-run only (smoke
# shapes are python-overhead-dominated and the 2% band is noise there);
# the parity / zero-compile / balanced-trace checks always gate.
OBS_TIMING_CHECK = ("serving obs: metrics-enabled warm path within 2% "
                    "median wall-clock of NullRegistry")
OBS_OVERHEAD_BOUND = 1.02
# Open-loop serving protocol (tail-latency SLO): requests arrive on a
# wall-clock schedule the server does not control. The p99 gate compares
# the async pipeline against the synchronous path at an arrival rate the
# ASYNC server sustains (gap = 1.15x its saturated per-turn service
# time): if the pipeline genuinely overlaps host bookkeeping with device
# execution, the sync path is overloaded at that rate and its queue —
# hence its p99 — grows with the trace, while async stays flat. Smoke
# keeps the gate in the exit code with a relaxed bound (tiny shapes on
# shared runners are scheduler-noise-dominated).
#
# The >= 1.3x target needs hardware concurrency: overlap requires the
# host thread and the XLA executor to run AT THE SAME TIME, so on a
# single-core CPU host (os.cpu_count() == 1, as in some CI containers)
# host+device work is serialized no matter how it is pipelined and the
# best async can do is tie. There the gate degrades to NON-REGRESSION:
# the pipeline's extra machinery must not make the tail meaningfully
# worse. The record carries `overlap_capable`/`host_cores` so a reader
# knows which regime a given artifact measured.
OPENLOOP_P99_CHECK = ("open-loop serving: async p99 turn latency >= 1.3x "
                      "better than sync (seeded Poisson; single-core "
                      "hosts gate non-regression)")
OPENLOOP_P99_RATIO = 1.3
OPENLOOP_P99_SINGLE_CORE = 0.75
OPENLOOP_WALL_CHECK = ("open-loop serving: async wall-clock <= sync "
                       "wall-clock (seeded Poisson; single-core hosts "
                       "gate non-regression)")
OPENLOOP_WALL_SINGLE_CORE = 0.85
OPENLOOP_TAIL_CHECK = ("open-loop serving: async p99/p50 tail ratio "
                       "bounded (Poisson, stable regime)")
OPENLOOP_TAIL_BOUND = 10.0
AUTOTUNE_CHECK = ("autotuner: chosen block >= 1.0x DEFAULT_BLOCK_N at "
                  "every benched point")
# Adaptive-precision cascade: the 1-bit sign prescreen reads D/8 bytes
# per probed row and the nibble stage then gathers only the C0
# survivors, so total stage-0 + stage-1 bytes vs the no-prescreen
# cascade is 4V / (V + 4*C0) — exactly 2x at the frontier point
# C0 = V/4. The model is analytic (engine.plan), so the gate holds in
# smoke too.
PRESCREEN_BYTES_CHECK = ("prescreen: stage-0+stage-1 bytes >= 1.5x below "
                         "the no-prescreen cascade (analytic, C0 = V/4)")
PRESCREEN_BYTES_RATIO = 1.5
# Serving-side half of the tentpole: at a CONSTRAINED slab budget (the
# regime where bytes actually move — preload pressure demotes, misses
# stream), the tiered cache + prescreen must beat the PR-5
# full-precision cache on total stage-0+stage-1 HBM bytes/query over
# the same trace, at unchanged recall.
TIER_BYTES_CHECK = ("precision tiers: stage-0+stage-1 HBM bytes/query "
                    "below the full-precision cache at the same budget")
TIER_BYTES_RATIO = 1.2
# Sharded serving: structural gates, NEVER excluded in smoke — placement
# invariance and exactly-once failover are correctness properties, not
# timings. The same section runs single-device (shards co-located) and,
# in the CI multidevice job, on a real forced-host 4-way mesh via
# --sharded-only.
SHARDED_PARITY_CHECK = ("sharded serving: 4-shard trace bit-identical "
                        "to the single-shard baseline")
SHARDED_FAILOVER_CHECK = ("sharded serving: mid-trace shard loss "
                          "completes with zero dropped / duplicated "
                          "requests")
SHARDED_RESTORE_CHECK = ("sharded serving: failover re-placed every "
                         "lost document and post-failure scores match "
                         "the baseline")
# Cascade-powered decode: the KV cache served as an engine corpus. The
# parity gate is structural (the engine-backed path must reproduce the
# legacy sparse-KV implementation bit-for-bit, including the empty/short
# cache edge cases, on both backends); the byte gate is the measured
# StagePlan ledger — per (layer, kv-head) per step the cascade streams
# T*hd/2 + 4T + k*(hd+4) + 2*k*hd bytes vs 4*T*hd dense, > 4x at
# k << T — analytic, so it gates in smoke too.
DECODE_PARITY_CHECK = ("decode: engine KV cascade bit-identical to legacy "
                       "sparse_decode_attention (lengths 0/<k/>=k, both "
                       "backends)")
DECODE_BYTES_CHECK = ("decode: dense-vs-sparse HBM bytes/step >= 4x at "
                      "k << T (measured ledger)")
DECODE_BYTES_RATIO = 4.0
DECODE_LEDGER_CHECK = ("decode: kv_plan StagePlan ledger reconciles with "
                       "sparse_bytes_per_step")
DECODE_TURN_CHECK = ("decode: end-to-end agent turn lands per-turn "
                     "uJ/token (and uJ/query) in one registry")


def _build(n, d, bmax, seed=0):
    rng = np.random.default_rng(seed)
    db = build_database(jnp.asarray(
        rng.normal(size=(n, d)).astype(np.float32)))
    bp = BitPlanarDB.from_quantized(db)
    q, _ = quantize_int8(jnp.asarray(
        rng.normal(size=(bmax, d)).astype(np.float32)), per_vector=True)
    return bp, q


def run(verbose=True, smoke=False):
    n, d = (512, 128) if smoke else (4096, 512)
    batches = (4,) if smoke else (8, 32, 128)
    reps = 3 if smoke else 5
    records: dict[str, dict] = {}
    # Tune FIRST: installation is trace-time, so running the measured
    # search before anything compiles means every later section — the
    # kernel sweeps, the cascade, the serving engines — traces with the
    # tuned shapes (and the parity checks below then cover them).
    tuned = _autotune_section(records, smoke=smoke, verbose=verbose)
    cfg = RetrievalConfig(k=5, metric="cosine")
    eng = RetrievalEngine(cfg)
    bp, q_all = _build(n, d, max(batches))
    plane_bytes = n * (d // 2)

    vmapped_stage1 = jax.jit(jax.vmap(
        lambda qm: ops.stage1_scores(qm, bp.msb_plane)))

    parity_ok, plan_ok = True, True
    for b in batches:
        q = q_all[:b]
        q_msb = msb_nibble(q)

        # ---- parity first: the batched kernel must equal the vmapped
        # scalar kernel bit-for-bit (both exact integer arithmetic).
        got = ops.stage1_scores_batched(q_msb, bp.msb_plane)
        want = vmapped_stage1(q_msb)
        parity_ok &= bool(jnp.array_equal(got, want))

        # ---- analytic bytes (exact): once per batch vs once per query.
        plan = eng.plan_for(bp, b)
        plan_ok &= (plan.stage1_bytes == plane_bytes
                    and plan.stage1_bytes_vmapped == b * plane_bytes)

        # ---- wall-clock: kernels (interpret on CPU) and jnp engine body.
        t_batched = _median_ms(ops.stage1_scores_batched, q_msb,
                               bp.msb_plane, reps=reps)
        t_vmapped = _median_ms(vmapped_stage1, q_msb, reps=reps)
        records[f"stage1_kernel_B{b}"] = {
            "median_ms": t_batched, "ref_median_ms": t_vmapped,
            "ratio": t_vmapped / t_batched,
            "bytes_streamed": plan.stage1_bytes,
            "bytes_streamed_vmapped": plan.stage1_bytes_vmapped,
        }

        def batched_engine(qq):
            return eng.retrieve(qq, bp)

        def per_query(qq):
            return [eng.retrieve_single(qq[i], bp)
                    for i in range(qq.shape[0])]
        t_eng = _median_ms(batched_engine, q, reps=reps)
        t_loop = _median_ms(per_query, q, reps=reps)
        records[f"two_stage_jnp_B{b}"] = {
            "median_ms": t_eng, "ref_median_ms": t_loop,
            "ratio": t_loop / t_eng,
        }

    if verbose:
        mode = ("smoke shapes, CPU interpret" if smoke else
                "CPU: Pallas interpret mode — kernel times are relative "
                "indicators; bytes are exact")
        print("== batched engine vs vmapped-scalar path "
              f"(N={n} D={d}; {mode}) ==")
        for name, r in records.items():
            if "median_ms" not in r:        # e.g. the autotune record
                continue
            line = (f"  {name:>22}: {r['median_ms']:9.2f} ms   "
                    f"ref {r['ref_median_ms']:9.2f} ms   "
                    f"speedup {r['ratio']:6.2f}x")
            if "bytes_streamed" in r:
                line += (f"   bytes {r['bytes_streamed']:>12,} vs "
                         f"{r['bytes_streamed_vmapped']:>14,}")
            print(line)
        print(f"  doc plane per batched launch: {plane_bytes:,} bytes "
              "(= N*D/2, streamed ONCE per batch)")

    cascade = _cascade_section(records, smoke=smoke, reps=reps,
                               verbose=verbose)
    serving = _serving_section(records, smoke=smoke, verbose=verbose)
    openloop = _openloop_section(records, smoke=smoke, verbose=verbose,
                                 index=serving["index"],
                                 queries_per_turn=serving["queries_per_turn"],
                                 cache_bytes=serving["plane_budget"])
    precision = _precision_section(records, smoke=smoke, verbose=verbose,
                                   serving=serving)
    sharded = _sharded_section(records, smoke=smoke, verbose=verbose)
    decode = _decode_section(records, smoke=smoke, verbose=verbose)

    mid = f"stage1_kernel_B{32 if not smoke else batches[0]}"
    checks = {
        "batched kernel == vmapped kernel bit-for-bit (all B)": parity_ok,
        "doc plane streamed exactly once per batch (analytic)": plan_ok,
        TIMING_CHECK: records[mid]["ratio"] > 1.0,
        "cascade jnp == pallas bit-for-bit": cascade["parity"],
        "cascade per-stage plan matches analytic byte model":
            cascade["plan_ok"],
        "cascade recall@k >= 0.95 vs full two-stage scan":
            cascade["recall"] >= 0.95,
        BYTES_CHECK: cascade["reduction"] >= 4.0,
        "serving runtime: warm cache >= 2x fewer stage-1 HBM bytes/query":
            serving["reduction"] >= 2.0,
        "serving runtime: warm results bit-identical to cold run":
            serving["warm_cold_parity"],
        "serving runtime: results match sequential per-request retrieval":
            serving["sequential_parity"],
        "serving runtime: recall@5 unchanged by the cache":
            serving["recall_warm"] == serving["recall_cold"],
        "serving trace recall@5 >= 0.9 vs planted gold":
            serving["recall_warm"] >= 0.9,
        SERVING_TIMING_CHECK:
            serving["time_ratio"] >= (SERVING_SMOKE_BOUND if smoke else 1.0),
        "serving obs: metrics-enabled results bit-identical to "
        "NullRegistry run": serving["obs_parity"],
        "serving obs: zero additional jit compiles with metrics enabled":
            serving["obs_zero_compiles"],
        "serving obs: one balanced submit->resolve span per request":
            serving["obs_trace_ok"],
        "serving obs: prometheus export parses with latency/energy series":
            serving["obs_prom_ok"],
        OBS_TIMING_CHECK: serving["obs_overhead"] <= OBS_OVERHEAD_BOUND,
        "prescreen jnp == pallas bit-for-bit (C0 = view/4)":
            cascade["ps_parity"],
        "prescreen plan ledger [prune,prescreen,approx,exact] matches "
        "analytic byte model": cascade["ps_plan_ok"],
        "prescreen recall@k unchanged vs no-prescreen cascade":
            cascade["ps_recall"] == cascade["recall"],
        PRESCREEN_BYTES_CHECK:
            cascade["ps_reduction"] >= PRESCREEN_BYTES_RATIO,
        TIER_BYTES_CHECK: precision["drop"] >= TIER_BYTES_RATIO,
        "precision tiers: recall@5 unchanged vs full-precision cache "
        "(same budget)":
            precision["recall_tier"] == precision["recall_base"],
        "precision tiers: demotion+promotion machinery exercised on the "
        "trace": precision["exercised"],
        AUTOTUNE_CHECK: tuned["ok"],
        "open-loop serving: async results bit-identical to sync "
        "(both arrival models)": openloop["parity"],
        OPENLOOP_P99_CHECK: openloop["p99_ratio_poisson"] >= (
            SERVING_SMOKE_BOUND if smoke
            else OPENLOOP_P99_RATIO if openloop["overlap_capable"]
            else OPENLOOP_P99_SINGLE_CORE),
        OPENLOOP_WALL_CHECK: openloop["wall_ratio"] >= (
            SERVING_SMOKE_BOUND if smoke
            else 1.0 if openloop["overlap_capable"]
            else OPENLOOP_WALL_SINGLE_CORE),
        OPENLOOP_TAIL_CHECK: openloop["tail_ratio"] <= OPENLOOP_TAIL_BOUND,
        DECODE_PARITY_CHECK: decode["parity"],
        DECODE_BYTES_CHECK: decode["ratio"] >= DECODE_BYTES_RATIO,
        DECODE_LEDGER_CHECK: decode["ledger_ok"],
        DECODE_TURN_CHECK: decode["turn_ok"],
    }
    checks.update(_sharded_checks(sharded))
    return {"records": records, "checks": checks}


def _sharded_checks(sec: dict) -> dict:
    return {
        SHARDED_PARITY_CHECK: sec["parity"],
        SHARDED_FAILOVER_CHECK: sec["exactly_once"],
        SHARDED_RESTORE_CHECK: sec["restore_ok"],
    }


def _decode_section(records, *, smoke, verbose):
    """Cascade-powered decode: the KV cache behind RetrievalEngine.

    Three sub-checks, mirroring the retrieval sections' discipline:
    (1) bit parity — the engine-backed `sparse_decode_attention` vs the
    legacy hand-rolled implementation across the edge-case lengths and
    both backends (paged full coverage must DEGENERATE to the same
    selection); (2) the measured byte ledger — `engine.kv_plan` priced by
    the same `energy.cost_cascade` as retrieval, reconciling with
    `sparse_bytes_per_step` and clearing the >= 4x dense-vs-sparse gate
    at k << T; (3) an end-to-end agent turn (tiny models) where ONE
    ServingRuntime schedules the retrieval launch and charges the decode
    cascade, landing per-turn uJ/token next to uJ/query in one registry.
    """
    from repro.core import energy as energy_mod
    from repro.core import engine as engine_mod
    from repro.models import embedder as emb_mod
    from repro.models.common import ModelConfig
    from repro.models.registry import get_model
    from repro.obs import MetricsRegistry
    from repro.serve import (MultiTenantRAGPipeline, RAGAgent,
                             RuntimeConfig, ServingRuntime, sparse_kv)

    # ---- (1) bit parity: engine cascade vs legacy implementation.
    rng = np.random.default_rng(11)
    b, t, kh, h, hd = 2, 64, 2, 4, 32
    kx = jnp.asarray(rng.normal(size=(b, t, kh, hd)), jnp.float32)
    vx = jnp.asarray(rng.normal(size=(b, t, kh, hd)), jnp.float32)
    qx = jnp.asarray(rng.normal(size=(b, 1, h, hd)), jnp.float32)
    cache = sparse_kv.build_quant_cache(kx, vx)
    l_full = jnp.full((b,), t, jnp.int32)
    cache_p = sparse_kv.build_page_centroids(cache, l_full, page_rows=8)
    parity = True
    ref_full = sparse_kv.sparse_decode_attention_ref(qx, cache, l_full, 16)
    for length in (0, 3, t):                    # empty / short / full
        ll = jnp.full((b,), length, jnp.int32)
        ref = sparse_kv.sparse_decode_attention_ref(qx, cache, ll, 16)
        got = sparse_kv.sparse_decode_attention(qx, cache, ll, 16)
        parity &= bool(jnp.array_equal(ref, got))
    for backend in ("jnp", "pallas"):
        paged = sparse_kv.sparse_decode_attention(
            qx, cache_p, l_full, 16, npages=t // 8, backend=backend)
        parity &= bool(jnp.array_equal(paged, ref_full))
        # pruned schedules have no legacy twin: gate backend agreement
        pr_j = sparse_kv.sparse_decode_attention(
            qx, cache_p, l_full, 8, npages=4, prescreen_c0=24,
            backend="jnp")
        pr_p = sparse_kv.sparse_decode_attention(
            qx, cache_p, l_full, 8, npages=4, prescreen_c0=24,
            backend="pallas")
        parity &= bool(jnp.array_equal(pr_j, pr_p))

    # ---- (2) measured byte ledger at a real decode shape.
    dt, dhd, dk, dkh, dqh, dlayers = ((2048, 128, 256, 8, 32, 4) if smoke
                                      else (32768, 128, 256, 8, 32, 16))
    flat_plan = engine_mod.kv_plan(
        engine_mod.KVCascadeConfig(top_k=dk), batch=4, kv_heads=dkh,
        q_heads=dqh, seq_len=dt, head_dim=dhd, layers=dlayers)
    lanes = 4 * dkh * dlayers
    sparse_lane = sum(s.bytes_hbm for s in flat_plan.stages) / lanes
    ledger_ok = sparse_lane == sparse_kv.sparse_bytes_per_step(dt, dhd, dk)
    dense_lane = sparse_kv.dense_bytes_per_step(dt, dhd)
    ratio = dense_lane / sparse_lane
    paged_plan = engine_mod.kv_plan(
        engine_mod.KVCascadeConfig(top_k=dk, npages=dt // 16 // 8,
                                   page_rows=16),
        batch=4, kv_heads=dkh, q_heads=dqh, seq_len=dt, head_dim=dhd,
        layers=dlayers)
    paged_lane = sum(s.bytes_hbm for s in paged_plan.stages) / lanes
    uj_tok = energy_mod.cost_cascade(flat_plan.stages, dhd,
                                     batch=flat_plan.batch).total_uj
    uj_tok_paged = energy_mod.cost_cascade(paged_plan.stages, dhd,
                                           batch=paged_plan.batch).total_uj
    records[f"decode_T{dt}"] = {
        "seq_len": dt, "head_dim": dhd, "top_k": dk, "layers": dlayers,
        "dense_bytes_per_step": dense_lane,
        "sparse_bytes_per_step": int(sparse_lane),
        "paged_bytes_per_step": int(paged_lane),
        "bytes_ratio": ratio,
        "paged_bytes_ratio": dense_lane / paged_lane,
        "uj_per_token": uj_tok,
        "uj_per_token_paged": uj_tok_paged,
        "parity": bool(parity),
        "ledger_reconciles": bool(ledger_ok),
    }

    # ---- (3) end-to-end agent turn through one runtime.
    emb_cfg = ModelConfig(name="bench-emb", family="dense", num_layers=1,
                          d_model=32, num_heads=2, num_kv_heads=2,
                          d_ff=64, vocab_size=64, pooled_dim=32)
    emb_params = emb_mod.init_params(emb_cfg, jax.random.PRNGKey(7))
    gen_cfg = ModelConfig(name="bench-gen", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=96, vocab_size=64)
    api = get_model(gen_cfg)
    gen_params = api.init(jax.random.PRNGKey(1))
    pipe = MultiTenantRAGPipeline.create(emb_cfg, emb_params, api,
                                         gen_params, capacity=64,
                                         doc_len=4)
    for tid in range(2):
        pipe.ingest(tid, rng.integers(0, 64, size=(6, 4)))
    reg = MetricsRegistry()
    rt = ServingRuntime(pipe.index,
                        RuntimeConfig(max_batch=2, auto_flush=False),
                        registry=reg)
    agent = RAGAgent(pipeline=pipe, runtime=rt, top_k=16, npages=4,
                     prescreen_c0=24, page_rows=8)
    qtok = jnp.asarray(rng.integers(0, 64, size=(2, 4)))
    rep = agent.turn(np.array([0, 1]), qtok, max_new=6, now=0.0)
    hist = reg.snapshot()["histograms"]
    turn_ok = (rep.uj_per_token > 0 and rep.uj_per_query > 0
               and hist.get("energy_uj_per_token", {}).get("count", 0) == 6
               and hist.get("energy_uj_per_query", {}).get("count", 0) >= 2)
    records["agent_turn"] = {
        "uj_per_query": rep.uj_per_query,
        "uj_per_token": rep.uj_per_token,
        "decode_bytes_per_token": rep.decode_bytes_per_token,
        "dense_bytes_per_token": rep.dense_bytes_per_token,
        "tokens_decoded": int(rt.decode_steps),
        "decode_bytes_hbm_total": int(rt.decode_bytes_hbm),
    }

    if verbose:
        print("== cascade-powered decode (KV cache as engine corpus) ==")
        print(f"  parity vs legacy (0/<k/>=k, both backends): {parity}")
        print(f"  decode_T{dt}: dense {dense_lane:,} B/step vs cascade "
              f"{int(sparse_lane):,} ({ratio:.2f}x) vs paged "
              f"{int(paged_lane):,} ({dense_lane / paged_lane:.2f}x) "
              f"per (layer, kv-head)")
        print(f"  uJ/token: flat {uj_tok:.2f}  paged {uj_tok_paged:.2f} "
              f"(B=4, {dlayers} layers)")
        print(f"  agent turn: {rep.uj_per_query:.3f} uJ/query + "
              f"{rep.uj_per_token:.3f} uJ/token through one runtime")
    return {"parity": bool(parity), "ratio": ratio,
            "ledger_ok": bool(ledger_ok), "turn_ok": bool(turn_ok)}


def _autotune_section(records, *, smoke, verbose):
    """Measured kernel autotuner: replaces the hand-found DEFAULT_BLOCK_N
    crossover with a timed search on THIS device. The winning table is
    installed process-wide (every later section traces with tuned
    shapes) and saved as the CI artifact `BENCH_autotune.json`, keyed by
    device kind so a run on other hardware refuses it."""
    from repro.kernels import autotune
    if smoke:
        table = autotune.autotune(n=512, d=128, batches=(1, 8),
                                  candidates=(128, 256, 1024), reps=1,
                                  kernels=("stage1_batched", "fused_topk"))
    else:
        table = autotune.autotune(reps=3)
    autotune.install(table)
    table.save("BENCH_autotune.json")
    ok = bool(table.entries) and all(e["speedup_vs_default"] >= 1.0
                                     for e in table.entries.values())
    records["autotune"] = {
        "signature": table.signature,
        "entries": {key: {"block_n": e["block_n"],
                          "default_block_n": e["default_block_n"],
                          "speedup_vs_default": e["speedup_vs_default"]}
                    for key, e in table.entries.items()},
    }
    if verbose:
        sig = table.signature
        print(f"== kernel block autotuner (device={sig['device_kind']} "
              f"backend={sig['backend']} interpret={sig['interpret']}) ==")
        for key in sorted(table.entries):
            e = table.entries[key]
            print(f"  {key:>20}: block {e['block_n']:>4}   "
                  f"default {e['default_block_n']:>4}   "
                  f"{e['speedup_vs_default']:5.2f}x vs default")
        print("  table installed for every later section; artifact: "
              "BENCH_autotune.json")
    return {"ok": ok, "table": table}


def _cascade_section(records, *, smoke, reps, verbose):
    """Cluster-pruned cascade vs the full two-stage scan on a synthetic
    clustered corpus (planted cluster structure; the codebook is the
    quantized planted centers refined by one k-means pass, so the bench
    isolates the CASCADE's cost/quality, not k-means convergence)."""
    if smoke:
        n, d, csize, nprobe, br, b = 2048, 128, 64, 4, 32, 4
    else:
        n, d, csize, nprobe, br, b = 65536, 256, 128, 8, 64, 8
    k = 5
    docs, queries, gold = retrieval_corpus(
        n, d, num_queries=max(b, 16), noise=0.1, cluster_size=csize,
        cluster_spread=0.2, seed=7)
    db = BitPlanarDB.from_quantized(build_database(jnp.asarray(docs)))
    # planted layout: rows are already cluster-grouped (row // csize)
    labels = (np.arange(n) // csize).astype(np.int32)
    num_clusters = int(labels[-1]) + 1
    centers = np.stack([docs[labels == c].mean(axis=0)
                        for c in range(num_clusters)])
    cents, _ = quantize_int8(jnp.asarray(centers.astype(np.float32)))
    codebook = clustering.ClusterCodebook.from_codes(cents)
    table = clustering.block_table(labels, num_clusters, br)
    cfg = RetrievalConfig(k=k, metric="cosine")
    q, _ = quantize_int8(jnp.asarray(queries[:b]), per_vector=True)

    full = batched_retrieve(q, db, cfg)
    pruned = cluster_pruned_retrieve(q, db, codebook, table, labels, cfg,
                                     nprobe=nprobe, block_rows=br)
    pruned_pl = cluster_pruned_retrieve(
        q, db, codebook, table, labels,
        RetrievalConfig(k=k, metric="cosine", backend="pallas"),
        nprobe=nprobe, block_rows=br)
    parity = bool(
        jnp.array_equal(pruned.indices, pruned_pl.indices)
        and jnp.array_equal(pruned.scores, pruned_pl.scores)
        and jnp.array_equal(pruned.candidate_indices,
                            pruned_pl.candidate_indices))
    fi, ci = np.asarray(full.indices), np.asarray(pruned.indices)
    recall = float(np.mean([len(set(fi[i]) & set(ci[i])) / k
                            for i in range(b)]))

    # ---- analytic per-stage bytes: the plan must equal the formulae.
    eng = RetrievalEngine(cfg)
    import repro.core.engine as engine_mod
    policy = engine_mod.ClusterPolicy(
        owner=jnp.zeros(n, jnp.int32), tenant_ids=jnp.zeros(b, jnp.int32),
        labels=jnp.asarray(labels), centroid_msb=codebook.msb_plane,
        centroid_norms=codebook.norms_sq, cluster_blocks=jnp.asarray(table),
        nprobe=nprobe, block_rows=br)
    plan = eng.plan_for(db, b, policy)
    full_plan = eng.plan_for(db, b)
    probe = nprobe * table.shape[1] * br
    plan_ok = (
        [s.name for s in plan.stages] == ["prune", "approx", "exact"]
        and plan.stages[0].bytes_hbm == num_clusters * (d // 2)
        and plan.stage1_bytes == b * probe * (d // 2)
        and plan.stage2_bytes == b * plan.candidates * d)
    reduction = full_plan.stage1_bytes / plan.stage1_bytes

    # ---- stage-0 sign prescreen at the frontier point C0 = V/4 --------
    # 1-bit sign-plane scores gate the nibble gather: stage 0 reads
    # probe * D/8 bytes, stage 1 shrinks to the C0 survivors. Survivor
    # indices are re-sorted into view order, so a generous C0 is
    # bit-identical to the no-prescreen cascade (pinned on the golden
    # corpus by tests/test_recall_regression.py); here the analytic
    # ledger, the jnp/pallas parity, and the recall are measured at
    # the 2x byte point.
    c0 = probe // 4
    cfg_ps = RetrievalConfig(k=k, metric="cosine", prescreen_c0=c0)
    ps = cluster_pruned_retrieve(q, db, codebook, table, labels, cfg_ps,
                                 nprobe=nprobe, block_rows=br)
    ps_pl = cluster_pruned_retrieve(
        q, db, codebook, table, labels,
        RetrievalConfig(k=k, metric="cosine", backend="pallas",
                        prescreen_c0=c0),
        nprobe=nprobe, block_rows=br)
    ps_parity = bool(
        jnp.array_equal(ps.indices, ps_pl.indices)
        and jnp.array_equal(ps.scores, ps_pl.scores)
        and jnp.array_equal(ps.candidate_indices, ps_pl.candidate_indices))
    pi = np.asarray(ps.indices)
    ps_recall = float(np.mean([len(set(fi[i]) & set(pi[i])) / k
                               for i in range(b)]))
    ps_identical = bool(jnp.array_equal(ps.indices, pruned.indices)
                        and jnp.array_equal(ps.scores, pruned.scores))
    plan_ps = RetrievalEngine(cfg_ps).plan_for(db, b, policy)
    ps_plan_ok = (
        [s.name for s in plan_ps.stages] == ["prune", "prescreen",
                                             "approx", "exact"]
        and plan_ps.stages[1].bits == 1
        and plan_ps.stages[1].bytes_hbm == b * probe * (d // 8)
        and plan_ps.stage1_bytes == b * c0 * (d // 2))
    ps_total = plan_ps.stages[1].bytes_hbm + plan_ps.stage1_bytes
    ps_reduction = plan.stage1_bytes / ps_total

    # ---- wall-clock: cascade vs full two-stage (jnp engine bodies).
    t_full = _median_ms(lambda qq: batched_retrieve(qq, db, cfg), q,
                        reps=reps)
    t_casc = _median_ms(
        lambda qq: cluster_pruned_retrieve(qq, db, codebook, table, labels,
                                           cfg, nprobe=nprobe,
                                           block_rows=br), q, reps=reps)
    t_ps = _median_ms(
        lambda qq: cluster_pruned_retrieve(qq, db, codebook, table, labels,
                                           cfg_ps, nprobe=nprobe,
                                           block_rows=br), q, reps=reps)
    records[f"cascade_jnp_B{b}"] = {
        "median_ms": t_casc, "ref_median_ms": t_full,
        "ratio": t_full / t_casc, "recall_at_k": recall,
        "bytes_streamed": plan.stage1_bytes,
        "bytes_streamed_full_scan": full_plan.stage1_bytes,
        "stage_bytes": {s.name: s.bytes_hbm for s in plan.stages},
    }
    records[f"prescreen_B{b}"] = {
        "median_ms": t_ps, "ref_median_ms": t_casc,
        "ratio": t_casc / t_ps,
        "prescreen_c0": c0, "view_rows": probe,
        "recall_at_k": ps_recall,
        "bit_identical_to_no_prescreen": ps_identical,
        "stage0_bytes": plan_ps.stages[1].bytes_hbm,
        "stage1_bytes": plan_ps.stage1_bytes,
        "stage01_bytes_no_prescreen": plan.stage1_bytes,
        "bytes_reduction": ps_reduction,
        "stage_bytes": {s.name: s.bytes_hbm for s in plan_ps.stages},
    }
    if verbose:
        print(f"== cluster-pruned cascade (N={n} D={d} K={num_clusters} "
              f"nprobe={nprobe} B={b}) ==")
        print(f"  cascade: {t_casc:9.2f} ms   full scan {t_full:9.2f} ms   "
              f"speedup {t_full / t_casc:5.2f}x   recall@{k} {recall:.3f}")
        print(f"  stage-1 bytes {plan.stage1_bytes:,} vs full "
              f"{full_plan.stage1_bytes:,} ({reduction:.1f}x less)   "
              "per-stage "
              f"{ {s.name: s.bytes_hbm for s in plan.stages} }")
        print(f"  sign prescreen (C0={c0} of view {probe}): "
              f"{t_ps:9.2f} ms   stage-0+1 bytes {ps_total:,} vs "
              f"{plan.stage1_bytes:,} ({ps_reduction:.1f}x less)   "
              f"recall@{k} {ps_recall:.3f}"
              f"{'   bit-identical' if ps_identical else ''}")
    return {"parity": parity, "recall": recall, "plan_ok": plan_ok,
            "reduction": reduction, "ps_parity": ps_parity,
            "ps_plan_ok": ps_plan_ok, "ps_recall": ps_recall,
            "ps_reduction": ps_reduction, "ps_identical": ps_identical}


def _session_trace(rng, *, tenants, turns, num_focus, zipf_s=1.1,
                   sticky=0.8):
    """Per-tenant correlated focus sequence: each turn a tenant keeps its
    current focus cluster with prob `sticky`, else redraws from a Zipf
    over the `num_focus` planted clusters — the wearable session shape
    (continuous monitoring re-probes the same clusters for many turns)."""
    ranks = np.arange(1, num_focus + 1, dtype=np.float64)
    pops = 1.0 / ranks ** zipf_s
    pops /= pops.sum()
    focus = rng.choice(num_focus, size=tenants, p=pops)
    trace = []
    for _ in range(turns):
        redraw = rng.random(tenants) >= sticky
        focus = np.where(redraw, rng.choice(num_focus, size=tenants, p=pops),
                         focus)
        trace.append(focus.copy())
    return trace


def _run_trace(index, queries_per_turn, *, cache_bytes, prior, rt=None,
               registry=None, tracer=None, tiers=False):
    """Drive one ServingRuntime over the prepared per-turn query batches.

    Blocks on every TURN's results before the next turn starts, so the
    per-turn timings measure COMPLETED retrieval work on both paths —
    jax dispatch is asynchronous, and a path that syncs per launch must
    not be compared against one that only enqueued its work (delivering
    each turn's results before the next is also what a real serving
    loop does). Pass `rt` to keep driving an existing runtime — the
    long-lived-session regime where a warm cache is steady-state.

    Returns (runtime, per-turn handle lists, per-turn seconds)."""
    from repro.serve.runtime import RuntimeConfig, ServingRuntime
    if rt is None:
        rt = ServingRuntime(index, RuntimeConfig(
            max_batch=len(queries_per_turn[0]), cache_bytes=cache_bytes,
            prior_clusters=prior, preload=cache_bytes > 0,
            auto_flush=False, precision_tiers=tiers),
            registry=registry, tracer=tracer)
    turns, per_turn = [], []
    for batch in queries_per_turn:
        t0 = time.perf_counter()
        handles = [rt.submit(t, q) for t, q, _ in batch]
        rt.flush()                         # barrier: drains the pipeline
        # result(wait=False) is now a None not-ready signal; result()
        # resolves, and blocking the indices keeps the timed region
        # honest even if materialization semantics change.
        jax.block_until_ready([h.result().indices for h in handles])
        per_turn.append(time.perf_counter() - t0)
        turns.append(handles)
    return rt, turns, per_turn


def _serving_section(records, *, smoke, verbose):
    """Hot-cluster cache on a correlated session trace: 8 tenants share a
    clustered arena; every turn each tenant's agent queries a noisy
    re-encoding of one of its own docs near its session's focus cluster.
    The SAME trace runs cold (cache disabled) and warm (budgeted cache +
    session prior); only the byte ledgers may differ."""
    from repro.core import RetrievalConfig
    from repro.core.clustering import ClusterParams
    from repro.tenancy import MultiTenantIndex

    if smoke:
        tenants, dpt, dim, kc, nprobe, br, turns = 8, 128, 64, 16, 4, 32, 6
    else:
        # 48 turns: the gate is a per-turn MEDIAN over long-lived
        # runtimes, so the trace must be long enough for the steady
        # state to dominate the sample (and for the median to be stable
        # against this container's multi-ms scheduler stalls).
        tenants, dpt, dim, kc, nprobe, br, turns = 8, 2048, 256, 64, 16, 32, 48
    k = 5
    capacity = -(-(tenants * dpt + kc) // br) * br
    rng = np.random.default_rng(11)
    centers = rng.normal(size=(kc, dim)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)

    index = MultiTenantIndex(capacity, dim, RetrievalConfig(k=k,
                                                            metric="cosine"),
                             clusters=ClusterParams(num_clusters=kc,
                                                    nprobe=nprobe,
                                                    block_rows=br))
    # Codebook bootstrap: the first ingested batch trains the online
    # k-means, so feeding it the planted centers pins the codebook to the
    # TRUE cluster structure — as in the cascade section, the bench
    # isolates the runtime/cache under test, not k-means convergence.
    index.ingest(0, jnp.asarray(centers))
    docs_of, slot_of, cluster_of = {}, {}, {}
    for t in range(tenants):
        planted = rng.integers(0, kc, dpt)
        docs = centers[planted] + 0.2 * rng.normal(size=(dpt, dim))
        docs = (docs / np.linalg.norm(docs, axis=1,
                                      keepdims=True)).astype(np.float32)
        slots = index.ingest(t, jnp.asarray(docs))
        docs_of[t], slot_of[t], cluster_of[t] = docs, slots, planted
    mapping = index.compact()    # (tenant, cluster)-grouped dense layout
    slot_of = {t: mapping[s] for t, s in slot_of.items()}

    def make_index(cfg2):
        """Rebuild an identical arena under a different RetrievalConfig:
        the ingest sequence is deterministic, so slots/layout — hence
        the trace's gold slots — carry over unchanged. Used by the
        precision-tier section, whose prescreen lives in the config."""
        idx2 = MultiTenantIndex(capacity, dim, cfg2,
                                clusters=ClusterParams(num_clusters=kc,
                                                       nprobe=nprobe,
                                                       block_rows=br))
        idx2.ingest(0, jnp.asarray(centers))
        for t2 in range(tenants):
            idx2.ingest(t2, jnp.asarray(docs_of[t2]))
        idx2.compact()
        return idx2

    # Per-turn query batches: one request per tenant, gold = its own doc.
    trace = _session_trace(rng, tenants=tenants, turns=turns, num_focus=kc)
    queries_per_turn = []
    for focus in trace:
        batch = []
        for t in range(tenants):
            mine = np.nonzero(cluster_of[t] == focus[t])[0]
            j = int(rng.choice(mine)) if mine.size else int(
                rng.integers(dpt))
            noisy = docs_of[t][j] + 0.1 * rng.normal(size=dim)
            qc, _ = quantize_int8(jnp.asarray(
                noisy.astype(np.float32)[None]), per_vector=True)
            batch.append((t, np.asarray(qc[0]), int(slot_of[t][j])))
        queries_per_turn.append(batch)

    # Budget sized so every (tenant, cluster) view fits AT ONCE, measured
    # from the actual block tables instead of a worst-case formula
    # (cached views are BLOCK-granular, so boundary blocks are stored
    # once per adjacent cluster and the per-key working set exceeds the
    # raw plane bytes — but a 4-blocks-per-view bound over-provisioned
    # the slab ~3x, and slab rows are real device memory the warm path
    # pays to allocate and scatter into). This is the VMEM-resident
    # regime — a v5e core holds ~16 MiB — and gives the cache's
    # upper-bound saving; the byte-budget shrinkage behavior is pinned
    # by tests/test_serve_runtime.py.
    demand_blocks = sum(
        int((index.cluster_layout(np.asarray([t], np.int32))[1] >= 0).sum())
        for t in range(tenants))
    plane_budget = demand_blocks * br * (dim // 2)
    # Timing protocol: the regression class this section gates is a
    # STEADY-STATE serving slowdown (the 0.43x warm path was slower on
    # every launch, not just while the cache filled), so both paths are
    # timed as a LONG-LIVED session server. One first pass per
    # configuration builds the runtime, compiles both paths' executables
    # (cold cascade / slab cascade + fill scatters) and pays the warm
    # path's cold-start fill phase — its wall-clock is recorded
    # separately as `first_pass_*` but does not decide the gate. The
    # timed passes then ALTERNATE cold/warm reps on the SAME runtimes
    # and the gate compares the per-path MEDIAN per-turn wall-clock: a
    # per-turn median is robust to the multi-ms scheduler stalls shared
    # CI machines inject (which a whole-trace total would pass straight
    # into the ratio), and a steady-state warm path that is slower than
    # the cold cascade still fails no matter how well it amortizes.
    reps = 1 if smoke else 3
    cold_rt, cold_turns, cold_first = _run_trace(
        index, queries_per_turn, cache_bytes=0, prior=0)
    warm_rt, warm_turns, warm_first = _run_trace(
        index, queries_per_turn, cache_bytes=plane_budget, prior=8)
    cold_pt, warm_pt = [], []
    for _ in range(reps):
        _, _, pt = _run_trace(index, queries_per_turn, cache_bytes=0,
                              prior=0, rt=cold_rt)
        cold_pt += pt
        _, _, pt = _run_trace(index, queries_per_turn,
                              cache_bytes=plane_budget, prior=8,
                              rt=warm_rt)
        warm_pt += pt
    t_cold = sorted(cold_pt)[len(cold_pt) // 2]
    t_warm = sorted(warm_pt)[len(warm_pt) // 2]

    # -- observability parity: metrics must be invisible to serving ------
    # A THIRD long-lived runtime serves the SAME trace through a real
    # MetricsRegistry + Tracer. Every executable it needs was compiled by
    # the runtimes above (identical shapes), so the jit cache sizes are
    # snapshotted around the entire metrics-enabled run: one extra trace
    # would mean instrumentation leaked into jitted code. Overhead is
    # then timed by ALTERNATING warm (NullRegistry) and obs reps on the
    # two steady-state runtimes and comparing per-turn medians.
    from repro.core import engine as engine_mod
    from repro.obs import (MetricsRegistry, Tracer, parse_prometheus,
                           prometheus_text)
    compiles_before = (engine_mod.retrieve_batched._cache_size()
                       + engine_mod.retrieve_batched_aux._cache_size())
    obs_reg, obs_tracer = MetricsRegistry(), Tracer()
    obs_rt, obs_turns, _ = _run_trace(
        index, queries_per_turn, cache_bytes=plane_budget, prior=8,
        registry=obs_reg, tracer=obs_tracer)
    # Windowed cache stats: snapshot + reset after the fill-phase pass so
    # the numbers below describe the STEADY STATE, not the cold start.
    fill_phase = obs_rt.cache.snapshot()
    obs_rt.cache.reset_stats()
    warm2_pt, obs_pt = [], []
    for _ in range(reps):
        _, _, pt = _run_trace(index, queries_per_turn,
                              cache_bytes=plane_budget, prior=8, rt=warm_rt)
        warm2_pt += pt
        _, _, pt = _run_trace(index, queries_per_turn,
                              cache_bytes=plane_budget, prior=8, rt=obs_rt)
        obs_pt += pt
    compiles_after = (engine_mod.retrieve_batched._cache_size()
                      + engine_mod.retrieve_batched_aux._cache_size())
    obs_zero_compiles = compiles_after == compiles_before
    obs_overhead = (sorted(obs_pt)[len(obs_pt) // 2]
                    / max(sorted(warm2_pt)[len(warm2_pt) // 2], 1e-9))
    steady = obs_rt.cache.snapshot()
    steady_hit_rate = steady["hits"] / max(steady["hits"]
                                           + steady["misses"], 1)
    obs_parity = True
    for wh, oh in zip(warm_turns, obs_turns):
        for w, o in zip(wh, oh):
            wr, orr = w.result(), o.result()
            obs_parity &= bool(
                jnp.array_equal(wr.indices, orr.indices)
                and jnp.array_equal(wr.scores, orr.scores)
                and jnp.array_equal(wr.candidate_indices,
                                    orr.candidate_indices))
    # Balanced trace: one B and one E "request" event per submission,
    # nothing left open after the final flush.
    n_begin = sum(e.ph == "B" for e in obs_tracer.spans("request"))
    n_end = sum(e.ph == "E" for e in obs_tracer.spans("request"))
    n_sub = obs_reg.get("counter", "serve_requests_submitted").value
    obs_trace_ok = (not obs_tracer.open_spans()
                    and n_begin == n_end == n_sub == obs_rt.queries_served)
    parsed = parse_prometheus(prometheus_text(obs_reg))
    obs_prom_ok = ("serve_queue_wait_seconds_bucket" in parsed
                   and "serve_queue_wait_seconds_count" in parsed
                   and "energy_uj_per_query_count" in parsed
                   and "cache_hits" in parsed)
    # Per-turn latency distributions (BENCH_retrieval.json currency):
    # samples go through the SAME log-bucketed histogram the runtime
    # uses, so the recorded p50/p95/p99 carry its documented error bound.
    lat = MetricsRegistry()
    for path, samples in (("cold", cold_pt), ("warm", warm_pt),
                          ("warm_obs", obs_pt)):
        h = lat.histogram("turn_seconds", path=path)
        for sec in samples:
            h.observe(sec)
    turn_latency_ms = {
        path: {pq: v * 1e3
               for pq, v in lat.histogram("turn_seconds",
                                          path=path).percentiles(
                                              (50, 95, 99)).items()}
        for path in ("cold", "warm", "warm_obs")}

    # -- parity: the cache may never change WHAT is retrieved ------------
    warm_cold = True
    hits = {"warm": 0, "cold": 0}
    seq_parity = True
    total = 0
    for turn, (ch, wh) in enumerate(zip(cold_turns, warm_turns)):
        for (t, q, gold), c, w in zip(queries_per_turn[turn], ch, wh):
            cr, wr = c.result(), w.result()
            warm_cold &= bool(
                jnp.array_equal(cr.indices, wr.indices)
                and jnp.array_equal(cr.scores, wr.scores)
                and jnp.array_equal(cr.candidate_indices,
                                    wr.candidate_indices))
            # Sequential reference: the same request dispatched as its
            # own one-lane launch (no cross-tenant batching, no cache).
            # Batching may regroup work but never change results.
            seq = index.retrieve(jnp.asarray(q)[None],
                                 np.asarray([t], np.int32))
            seq_parity &= bool(
                jnp.array_equal(wr.indices, seq.indices[0])
                and jnp.array_equal(wr.scores, seq.scores[0]))
            hits["cold"] += int(gold in np.asarray(cr.indices)[:k])
            hits["warm"] += int(gold in np.asarray(wr.indices)[:k])
            total += 1
    recall_cold = hits["cold"] / total
    recall_warm = hits["warm"] / total
    cold_bpq = cold_rt.stage1_bytes_streamed / cold_rt.queries_served
    warm_bpq = warm_rt.stage1_bytes_streamed / warm_rt.queries_served
    reduction = cold_bpq / max(warm_bpq, 1e-9)
    cache = warm_rt.cache_stats()
    hit_rate = cache["hits"] / max(cache["hits"] + cache["misses"], 1)
    uj_cold = cold_rt.energy_ledger().total_uj
    uj_warm = warm_rt.energy_ledger().total_uj

    time_ratio = t_cold / max(t_warm, 1e-9)
    records[f"serving_runtime_T{tenants}"] = {
        "median_ms": t_warm * 1e3, "ref_median_ms": t_cold * 1e3,
        "ratio": time_ratio,
        "time_ratio": time_ratio,
        # Cold-start accounting (NOT gated): the warm runtime's first
        # pass over the trace, paying slab allocation + every fill.
        "first_pass_warm_ms_per_turn": sum(warm_first) * 1e3 / turns,
        "first_pass_cold_ms_per_turn": sum(cold_first) * 1e3 / turns,
        "stage1_hbm_bytes_per_query_warm": warm_bpq,
        "stage1_hbm_bytes_per_query_cold": cold_bpq,
        "hbm_reduction": reduction,
        "stage1_sram_bytes_total": warm_rt.stage1_bytes_sram,
        "cache_hit_rate": hit_rate,
        "recall_at_k": recall_warm,
        # energy_ledger() prices the FINAL launch's measured plan (the
        # trace's steady state: fully-warm vs always-cold); the byte
        # fields above are trace-wide totals.
        "uj_per_query_last_launch_warm": uj_warm,
        "uj_per_query_last_launch_cold": uj_cold,
        # trace-level µJ/query distribution from the metrics-enabled run
        # (every launch priced its measured plan, batch-weighted).
        "uj_per_query_dist": obs_reg.get(
            "histogram", "energy_uj_per_query").percentiles((50, 95, 99)),
        "turn_latency_ms": turn_latency_ms,
        "obs_overhead_ratio": obs_overhead,
        "cache_hit_rate_fill_phase": (
            fill_phase["hits"] / max(fill_phase["hits"]
                                     + fill_phase["misses"], 1)),
        "cache_hit_rate_steady_state": steady_hit_rate,
    }
    if verbose:
        print(f"== serving runtime: correlated session trace (T={tenants} "
              f"N={capacity} K={kc} nprobe={nprobe} turns={turns}) ==")
        print(f"  stage-1 HBM bytes/query: cold {cold_bpq:,.0f} -> warm "
              f"{warm_bpq:,.0f} ({reduction:.1f}x less; "
              f"{warm_rt.stage1_bytes_sram:,} B served from cache, "
              f"hit rate {hit_rate:.2f})")
        print(f"  energy (final steady-state launch): cold {uj_cold:.2f} "
              f"uJ/query -> warm {uj_warm:.2f} uJ/query")
        print(f"  recall@{k}: cold {recall_cold:.3f} warm {recall_warm:.3f}"
              f"   steady-state wall-clock/turn (median): cold "
              f"{t_cold * 1e3:.2f} ms warm {t_warm * 1e3:.2f} ms "
              f"({time_ratio:.2f}x, warm must not be slower; warm "
              f"first pass incl. fills "
              f"{sum(warm_first) * 1e3 / turns:.1f} ms/turn)")
        lat_w = turn_latency_ms["warm"]
        lat_c = turn_latency_ms["cold"]
        print(f"  per-turn latency (ms): warm p50/p95/p99 "
              f"{lat_w['p50']:.2f}/{lat_w['p95']:.2f}/{lat_w['p99']:.2f}"
              f"   cold {lat_c['p50']:.2f}/{lat_c['p95']:.2f}/"
              f"{lat_c['p99']:.2f}")
        fill_hit_rate = records[
            f"serving_runtime_T{tenants}"]["cache_hit_rate_fill_phase"]
        print(f"  observability: overhead {obs_overhead:.3f}x (median, "
              f"metrics+trace on), new jit compiles "
              f"{compiles_after - compiles_before}, steady-state cache "
              f"hit rate {steady_hit_rate:.2f} "
              f"(fill phase {fill_hit_rate:.2f})")
    return {"reduction": reduction, "warm_cold_parity": warm_cold,
            "sequential_parity": seq_parity, "recall_warm": recall_warm,
            "recall_cold": recall_cold, "time_ratio": time_ratio,
            "obs_parity": obs_parity, "obs_zero_compiles": obs_zero_compiles,
            "obs_trace_ok": obs_trace_ok, "obs_prom_ok": obs_prom_ok,
            "obs_overhead": obs_overhead,
            # non-serialized: the open-loop + precision sections reuse
            # the corpus/trace
            "index": index, "queries_per_turn": queries_per_turn,
            "plane_budget": plane_budget, "make_index": make_index}


# ---------------------------------------------------------------------------
# Adaptive-precision tiers: constrained-budget serving comparison
# ---------------------------------------------------------------------------

def _precision_section(records, *, smoke, verbose, serving):
    """Serving half of the adaptive-precision cascade: the SAME session
    trace runs at a CONSTRAINED slab budget (1/4 of the every-view-
    resident budget the warm section uses) through (a) the PR-5
    full-precision cache and (b) the tiered cache + stage-0 sign
    prescreen. The tight budget is the regime where bytes actually
    move — preload pressure demotes full entries to the 1-bit sign
    tier, cold misses admit at SIGN and promote on re-probe, and the
    prescreen prorates every stage-1 miss to its C0 survivors — so the
    total stage-0+stage-1 HBM bytes/query ledger separates the two
    designs instead of both rounding to zero as they do fully
    resident. Results must stay recall-identical (and are recorded
    bit-identical when they are)."""
    from repro.core import RetrievalConfig

    index = serving["index"]
    queries_per_turn = serving["queries_per_turn"]
    budget = serving["plane_budget"] // 4
    k = index.cfg.k
    # frontier C0: ~1/4 of the steady-state probe view (see the golden
    # recall suite for the sweep that pins this as recall-neutral)
    c0 = 32 if smoke else 128
    reps = 1 if smoke else 2
    index_ps = serving["make_index"](
        RetrievalConfig(k=k, metric="cosine", prescreen_c0=c0))

    base_rt, base_turns, _ = _run_trace(index, queries_per_turn,
                                        cache_bytes=budget, prior=8)
    tier_rt, tier_turns, _ = _run_trace(index_ps, queries_per_turn,
                                        cache_bytes=budget, prior=8,
                                        tiers=True)
    base_pt, tier_pt = [], []
    for _ in range(reps):
        _, _, pt = _run_trace(index, queries_per_turn, cache_bytes=budget,
                              prior=8, rt=base_rt)
        base_pt += pt
        _, _, pt = _run_trace(index_ps, queries_per_turn,
                              cache_bytes=budget, prior=8, rt=tier_rt,
                              tiers=True)
        tier_pt += pt

    # Total stage-0 + stage-1 HBM bytes/query over identical pass
    # counts (fill + reps): the baseline has no stage 0, the tiered
    # path pays the sign plane for missing clusters and prorated
    # nibble gathers for the survivors.
    base_bpq = base_rt.stage1_bytes_streamed / base_rt.queries_served
    tier_bpq = ((tier_rt.stage1_bytes_streamed
                 + tier_rt.stage_bytes.get("prescreen", 0))
                / tier_rt.queries_served)
    drop = base_bpq / max(tier_bpq, 1e-9)

    parity = True
    hits = {"base": 0, "tier": 0}
    total = 0
    for turn, (bh, th) in enumerate(zip(base_turns, tier_turns)):
        for (t, _q, gold), hb, ht in zip(queries_per_turn[turn], bh, th):
            rb, rt_ = hb.result(), ht.result()
            parity &= bool(jnp.array_equal(rb.indices, rt_.indices)
                           and jnp.array_equal(rb.scores, rt_.scores))
            hits["base"] += int(gold in np.asarray(rb.indices)[:k])
            hits["tier"] += int(gold in np.asarray(rt_.indices)[:k])
            total += 1
    cache_stats = tier_rt.cache.snapshot()
    exercised = (cache_stats.get("demotions", 0) > 0
                 and cache_stats.get("promotions", 0) > 0)
    t_base = sorted(base_pt)[len(base_pt) // 2]
    t_tier = sorted(tier_pt)[len(tier_pt) // 2]

    tenants = len(queries_per_turn[0])
    records[f"serving_precision_T{tenants}"] = {
        "median_ms": t_tier * 1e3, "ref_median_ms": t_base * 1e3,
        "ratio": t_base / max(t_tier, 1e-9),
        "slab_budget_bytes": budget,
        "prescreen_c0": c0,
        "stage01_hbm_bytes_per_query_full_precision": base_bpq,
        "stage01_hbm_bytes_per_query_tiered": tier_bpq,
        "hbm_reduction": drop,
        "stage0_hbm_bytes_total": tier_rt.stage_bytes.get("prescreen", 0),
        "stage0_sram_bytes_total": tier_rt.stage_bytes_sram.get(
            "prescreen", 0),
        "recall_at_k_full_precision": hits["base"] / total,
        "recall_at_k_tiered": hits["tier"] / total,
        "bit_identical": parity,
        "cache": {key: cache_stats[key]
                  for key in ("hits", "misses", "evictions", "demotions",
                              "promotions", "sign_entries", "full_entries")
                  if key in cache_stats},
    }
    if verbose:
        print(f"== adaptive-precision tiers (budget/4 = {budget:,} B, "
              f"C0={c0}, T={tenants}) ==")
        print(f"  stage-0+1 HBM bytes/query: full-precision "
              f"{base_bpq:,.0f} -> tiered {tier_bpq:,.0f} "
              f"({drop:.2f}x less; stage-0 HBM "
              f"{tier_rt.stage_bytes.get('prescreen', 0):,} B, "
              f"on-chip {tier_rt.stage_bytes_sram.get('prescreen', 0):,} B)")
        print(f"  recall@{k}: full-precision {hits['base'] / total:.3f} "
              f"tiered {hits['tier'] / total:.3f}"
              f"{'   bit-identical results' if parity else ''}")
        print(f"  tier churn: demotions {cache_stats.get('demotions', 0)} "
              f"promotions {cache_stats.get('promotions', 0)} "
              f"evictions {cache_stats.get('evictions', 0)} "
              f"resident full/sign {cache_stats.get('full_entries', 0)}/"
              f"{cache_stats.get('sign_entries', 0)}   wall-clock/turn "
              f"tiered {t_tier * 1e3:.2f} ms vs {t_base * 1e3:.2f} ms")
    return {"drop": drop, "parity": parity,
            "recall_base": hits["base"] / total,
            "recall_tier": hits["tier"] / total, "exercised": exercised}


# ---------------------------------------------------------------------------
# Open-loop serving: arrival-driven tail latency
# ---------------------------------------------------------------------------

def _poisson_arrivals(rng, turns, gap):
    """Seeded Poisson process: i.i.d. exponential inter-arrivals with
    mean `gap` seconds."""
    return np.cumsum(rng.exponential(gap, size=turns))


def _bursty_arrivals(rng, turns, gap):
    """Two-state Markov-modulated Poisson process: a FAST state (mean
    0.4*gap) and a SLOW state (mean 1.6*gap) with symmetric switch
    probability 0.3 per arrival — stationary mix keeps the long-run rate
    at ~1/gap while clumping arrivals into bursts that briefly exceed
    even the async service rate (the tail-latency shape wearable agents
    produce: quiet monitoring punctuated by event flurries)."""
    out, t, state = [], 0.0, 0
    for _ in range(turns):
        t += float(rng.exponential(gap * (0.4 if state == 0 else 1.6)))
        out.append(t)
        if rng.random() < 0.3:
            state = 1 - state
    return np.asarray(out)


def _drive_openloop(index, queries_per_turn, arrivals, *, depth,
                    cache_bytes, registry=None):
    """Serve the trace open-loop: turn i's batch is submitted when the
    wall clock reaches arrivals[i], ready or not. Between arrivals the
    driver reaps finished launches (the async pipeline's lazy-retire
    path); per-turn latency is measured from the SCHEDULED arrival to
    the instant all of the turn's handles are resolved, so a backlogged
    server pays its queue in the tail. One untimed closed-loop pass
    first: compiles both paths and fills the cache to steady state."""
    from repro.serve.runtime import RuntimeConfig, ServingRuntime
    rt = ServingRuntime(index, RuntimeConfig(
        max_batch=len(queries_per_turn[0]), cache_bytes=cache_bytes,
        prior_clusters=8 if cache_bytes else 0, preload=cache_bytes > 0,
        auto_flush=True, async_depth=depth), registry=registry)
    for batch in queries_per_turn:          # untimed warm pass
        for t, q, _ in batch:
            rt.submit(t, q)
        rt.flush()

    pending, lat, all_handles = [], [], []

    def now():
        return time.perf_counter() - t0

    def harvest():
        # launches retire FIFO, so turn completion is FIFO too
        while pending and all(h.done() for h in pending[0][1]):
            arr, _ = pending.pop(0)
            lat.append(now() - arr)

    t0 = time.perf_counter()
    for batch, arr in zip(queries_per_turn, arrivals):
        while True:
            remaining = arr - now()
            if remaining <= 0:
                break
            rt.reap()
            harvest()
            # YIELD, never hot-spin: a spinning driver starves the XLA
            # executor of the very cycles the in-flight launches need
            # (fatal on few-core hosts), and burying the core in
            # is_ready() probes is not part of any serving protocol.
            time.sleep(min(2e-4, max(remaining, 0.0)))
        hs = [rt.submit(t, q, now=now()) for t, q, _ in batch]
        all_handles.append(hs)
        pending.append((arr, hs))
        harvest()
    rt.flush()                              # drain + barrier
    harvest()
    wall = now()
    assert not pending, "open-loop drive left unresolved turns"
    return rt, all_handles, np.asarray(lat), wall


def _openloop_section(records, *, smoke, verbose, index, queries_per_turn,
                      cache_bytes):
    """Tail-latency SLO protocol: the closed-loop sections above measure
    service time; real serving is OPEN-LOOP — arrivals do not wait for
    the server, so latency = queueing + service and the p99 exposes
    whether the async pipeline's overlap buys real headroom. Both
    arrival models are seeded; the same schedules drive the sync
    (async_depth=0) and async (async_depth=2) paths over the same warm
    corpus, and results must be bit-identical."""
    turns = len(queries_per_turn)
    tenants = len(queries_per_turn[0])
    seed = 1234
    host_cores = os.cpu_count() or 1
    # Overlap needs hardware concurrency: a non-CPU backend executes on
    # the accelerator while the host queues, and a multi-core CPU host
    # runs the XLA executor beside the driver. One CPU core has neither
    # — the async win degrades to "don't regress" (see constants above).
    overlap_capable = jax.default_backend() != "cpu" or host_cores > 1

    # -- calibrate: saturated (all-arrivals-at-0) per-turn service time --
    t_pt = {}
    for mode, depth in (("sync", 0), ("async", 2)):
        _, _, _, wall = _drive_openloop(
            index, queries_per_turn, np.zeros(turns), depth=depth,
            cache_bytes=cache_bytes)
        t_pt[mode] = wall / turns
    gap = 1.15 * t_pt["async"]

    models = {
        "poisson": _poisson_arrivals(np.random.default_rng(seed), turns,
                                     gap),
        "bursty": _bursty_arrivals(np.random.default_rng(seed + 1), turns,
                                   gap),
    }
    from repro.obs import MetricsRegistry
    lat_ms, walls, handles, breakdown = {}, {}, {}, {}
    for model, arrivals in models.items():
        lat_ms[model], walls[model], handles[model] = {}, {}, {}
        for mode, depth in (("sync", 0), ("async", 2)):
            reg = MetricsRegistry()         # fresh window per measured run
            rt, hs, lat, wall = _drive_openloop(
                index, queries_per_turn, arrivals, depth=depth,
                cache_bytes=cache_bytes, registry=reg)
            lat_ms[model][mode] = {
                f"p{p}": float(np.percentile(lat, p)) * 1e3
                for p in (50, 95, 99)}
            walls[model][mode] = wall
            handles[model][mode] = hs
            if model == "poisson":
                qw = reg.get("histogram", "serve_queue_wait_seconds")
                rl = reg.get("histogram", "serve_resolve_lag_seconds")
                breakdown[mode] = {
                    "queue_wait_ms": {p: v * 1e3 for p, v in
                                      qw.percentiles((50, 99)).items()},
                    "resolve_lag_ms": {p: v * 1e3 for p, v in
                                       rl.percentiles((50, 99)).items()},
                }

    parity = True
    for model in models:
        for hs_s, hs_a in zip(handles[model]["sync"],
                              handles[model]["async"]):
            for s, a in zip(hs_s, hs_a):
                rs, ra = s.result(), a.result()
                parity &= bool(
                    jnp.array_equal(rs.indices, ra.indices)
                    and jnp.array_equal(rs.scores, ra.scores)
                    and jnp.array_equal(rs.candidate_indices,
                                        ra.candidate_indices))

    p99_ratio = {m: lat_ms[m]["sync"]["p99"] / max(lat_ms[m]["async"]["p99"],
                                                   1e-9)
                 for m in models}
    tail_ratio = (lat_ms["poisson"]["async"]["p99"]
                  / max(lat_ms["poisson"]["async"]["p50"], 1e-9))
    wall_ratio = walls["poisson"]["sync"] / max(walls["poisson"]["async"],
                                                1e-9)
    records[f"serving_openloop_T{tenants}"] = {
        "arrival_seed": seed,
        "arrival_gap_ms": gap * 1e3,
        "host_cores": host_cores,
        "overlap_capable": overlap_capable,
        "service_ms_per_turn": {m: t_pt[m] * 1e3 for m in t_pt},
        "turn_latency_ms": lat_ms,
        "wall_s": walls,
        "p99_ratio": p99_ratio,
        "tail_ratio_async_poisson": tail_ratio,
        "queue_wait_vs_resolve_lag": breakdown,
    }
    if verbose:
        regime = ("overlap-capable" if overlap_capable
                  else f"single-core host ({host_cores} core, "
                       f"non-regression gates)")
        print(f"== open-loop serving (T={tenants} turns={turns} "
              f"gap={gap * 1e3:.2f} ms = 1.15x async service; "
              f"seed={seed}; {regime}) ==")
        print(f"  saturated service ms/turn: sync "
              f"{t_pt['sync'] * 1e3:.2f}   async {t_pt['async'] * 1e3:.2f}")
        for m in models:
            s, a = lat_ms[m]["sync"], lat_ms[m]["async"]
            print(f"  {m:>8}: sync  p50/p99 {s['p50']:8.2f}/{s['p99']:8.2f}"
                  f" ms   async p50/p99 {a['p50']:8.2f}/{a['p99']:8.2f} ms"
                  f"   p99 ratio {p99_ratio[m]:5.2f}x")
        bd = breakdown["async"]
        print(f"  async breakdown (poisson): queue wait p50/p99 "
              f"{bd['queue_wait_ms']['p50']:.2f}/"
              f"{bd['queue_wait_ms']['p99']:.2f} ms   resolve lag p50/p99 "
              f"{bd['resolve_lag_ms']['p50']:.2f}/"
              f"{bd['resolve_lag_ms']['p99']:.2f} ms")
    return {"parity": parity, "p99_ratio_poisson": p99_ratio["poisson"],
            "wall_ratio": wall_ratio, "tail_ratio": tail_ratio,
            "overlap_capable": overlap_capable, "host_cores": host_cores}


# ---------------------------------------------------------------------------
# Sharded serving: placement invariance + elastic failover
# ---------------------------------------------------------------------------

def _sharded_section(records, *, smoke, verbose):
    """Pod-scale sharded serving over the elastic failover path: the SAME
    mixed-tenant trace runs on (a) a single shard, (b) a 4-shard
    placement, and (c) a 4-shard placement that LOSES a shard mid-trace.
    Gates are structural, not timed: (b) must be bit-identical to (a) —
    tenant->shard placement is an implementation detail that may never
    change answers — and (c) must complete every request exactly once
    (ledger-proved zero dropped / duplicated) with scores equal to the
    baseline. On a 1-device host the four shards co-locate; the CI
    multidevice job re-runs this section on a real forced-host 4-way
    mesh (--sharded-only), where each shard owns a device."""
    from repro.core.retrieval import RetrievalConfig
    from repro.serve.runtime import RuntimeConfig
    from repro.serve.sharded import (ShardedRuntimeConfig,
                                     ShardedServingRuntime)

    tenants, dpt, dim, rounds = (6, 32, 64, 3) if smoke else (12, 256, 128, 8)
    shards = 4
    rng = np.random.default_rng(29)
    docs = {t: rng.integers(-40, 41, (dpt, dim), dtype=np.int8)
            for t in range(tenants)}
    trace = [(t, rng.integers(-40, 41, (dim,), dtype=np.int8))
             for t in list(range(tenants)) * rounds]
    devices = jax.devices()
    # max_candidates >= docs/tenant: the documented bit-parity
    # precondition (the stage-1 budget scales with per-shard occupancy,
    # which differs across placements).
    rcfg = RetrievalConfig(k=5, metric="mips", candidate_frac=1.0,
                           max_candidates=max(50, dpt))

    def build(s):
        cfg = ShardedRuntimeConfig(
            num_shards=s, capacity_per_shard=tenants * dpt, dim=dim,
            retrieval=rcfg,
            runtime=RuntimeConfig(max_batch=tenants, max_wait=1.0,
                                  cache_bytes=0, auto_flush=False))
        rt = ShardedServingRuntime(cfg, devices=devices[:s])
        for t in range(tenants):
            rt.ingest_codes(t, docs[t])
        return rt

    def drive(rt, fail_at=None):
        out, now, report = [], 0.0, None
        t0 = time.perf_counter()
        for i, (t, q) in enumerate(trace):
            if fail_at is not None and i == fail_at:
                report = rt.fail_shard(rt.live_shards[0], now=now)
            now += 1e-3
            out.append(rt.submit(t, q, now=now))
            if i % tenants == tenants - 1:
                rt.poll(now=now)
        rt.flush(now=now + 1)
        wall = time.perf_counter() - t0
        return ([(np.asarray(h.result().indices),
                  np.asarray(h.result().scores)) for h in out],
                wall, report)

    base, wall_1, _ = drive(build(1))
    multi_rt = build(shards)
    multi, wall_n, _ = drive(multi_rt)
    parity = all(np.array_equal(i1, iN) and np.array_equal(s1, sN)
                 for (i1, s1), (iN, sN) in zip(base, multi))

    lossy_rt = build(shards)
    lossy, _, report = drive(lossy_rt, fail_at=len(trace) // 2)
    led = lossy_rt.ledger()
    exactly_once = (led["submitted"] == led["resolved"] == len(trace)
                    and led["outstanding"] == 0
                    and led["dropped"] == 0 and led["duplicated"] == 0
                    and led["failovers"] == 1)
    restore_ok = (report is not None
                  and report["docs_restored"]
                  == dpt * len(report["moved_tenants"])
                  and len(lossy_rt.live_shards) == shards - 1
                  and all(np.array_equal(s1, sL)
                          for (_, s1), (_, sL) in zip(base, lossy)))

    records[f"serving_sharded_T{tenants}"] = {
        "shards": shards,
        "devices_used": len({str(s.device)
                             for s in multi_rt._shards.values()}),
        "requests": len(trace),
        "wall_s_single_shard": wall_1,
        "wall_s_multi_shard": wall_n,
        "bit_identical_to_single_shard": parity,
        "placement": {str(t): multi_rt.placement.shard_of(t)
                      for t in range(tenants)},
        "failover": {
            "lost_shard": report["shard"],
            "moved_tenants": report["moved_tenants"],
            "docs_restored": report["docs_restored"],
            "requests_resubmitted": report["requests_resubmitted"],
            "ledger": {key: led[key] for key in
                       ("submitted", "resolved", "dropped", "duplicated",
                        "resubmitted", "failovers")},
        },
        "shard_lanes_served": {str(s): n for s, n in
                               led["shard_lanes_served"].items()},
    }
    if verbose:
        print(f"== sharded serving + elastic failover (T={tenants} "
              f"docs/tenant={dpt} shards={shards} requests={len(trace)} "
              f"devices={len(devices)}) ==")
        print(f"  4-shard bit-identical to 1-shard: {parity}   "
              f"wall {wall_n:.2f}s vs {wall_1:.2f}s single")
        print(f"  failover: lost shard {report['shard']}, moved tenants "
              f"{report['moved_tenants']}, restored "
              f"{report['docs_restored']} docs, resubmitted "
              f"{report['requests_resubmitted']} in-flight requests")
        print(f"  ledger: {led['resolved']}/{led['submitted']} resolved, "
              f"dropped {led['dropped']}, duplicated {led['duplicated']} "
              f"(exactly-once: {exactly_once})")
    return {"parity": parity, "exactly_once": exactly_once,
            "restore_ok": restore_ok}


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    if "--sharded-only" in sys.argv:
        # The CI multidevice job's entry point: just the sharded section,
        # on whatever device set XLA_FLAGS forced. All its checks gate.
        records: dict[str, dict] = {}
        sec = _sharded_section(records, smoke=smoke, verbose=True)
        checks = _sharded_checks(sec)
        print(checks)
        if "--json" in sys.argv:
            import json
            path = sys.argv[sys.argv.index("--json") + 1]
            with open(path, "w") as f:
                json.dump({"retrieval_bench": records}, f, indent=2,
                          sort_keys=True)
            print(f"wrote {path}")
        sys.exit(0 if all(checks.values()) else 1)
    out = run(verbose=True, smoke=smoke)
    print(out["checks"])
    if "--json" in sys.argv:   # standalone record dump (CI artifact)
        import json
        path = sys.argv[sys.argv.index("--json") + 1]
        with open(path, "w") as f:
            json.dump({"retrieval_bench": out["records"]}, f, indent=2,
                      sort_keys=True)
        print(f"wrote {path}")
    gating = {k: v for k, v in out["checks"].items()
              if not (smoke and k in (TIMING_CHECK, BYTES_CHECK,
                                      OBS_TIMING_CHECK,
                                      OPENLOOP_TAIL_CHECK))}
    sys.exit(0 if all(gating.values()) else 1)
