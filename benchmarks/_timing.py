"""Shared wall-clock timer for the benchmark modules.

One methodology everywhere: the warmup call is BLOCKED (so the first
timed rep never absorbs a still-executing async dispatch tail), then the
reported figure is the median of `reps` fully-blocked timings — robust to
the occasional preemption spike on shared machines.
"""
from __future__ import annotations

import time

import jax


def median_ms(fn, *args, reps: int = 5) -> float:
    """Median wall-clock of ``fn(*args)`` over `reps` runs, in ms."""
    jax.block_until_ready(fn(*args))       # compile/warm outside the clock
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2] * 1e3
