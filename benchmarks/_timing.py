"""Shared wall-clock timer for the benchmark modules.

One methodology everywhere: jax dispatch is ASYNCHRONOUS, so a timed
region that does not `block_until_ready` every device output it produced
measures the enqueue, not the work. Every timed rep here is fully
synchronized; the warmup call is BLOCKED too (so the first timed rep
never absorbs a still-executing async dispatch tail), then the reported
figure is the median of `reps` fully-blocked timings — robust to the
occasional preemption spike on shared machines.
"""
from __future__ import annotations

import time

import jax


def wall_seconds(fn, *args) -> float:
    """One fully-synchronized wall-clock measurement of ``fn(*args)``,
    in seconds: the clock stops only after every device output is ready.
    Callers timing their own regions (e.g. the serving sections) must
    uphold the same discipline — block on every timed device output
    inside the region."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


def median_ms(fn, *args, reps: int = 5) -> float:
    """Median wall-clock of ``fn(*args)`` over `reps` runs, in ms."""
    jax.block_until_ready(fn(*args))       # compile/warm outside the clock
    times = sorted(wall_seconds(fn, *args) for _ in range(reps))
    return times[len(times) // 2] * 1e3
