"""Kernel microbenchmark: Pallas stage1/stage2/fused vs pure-jnp reference.

This container is CPU-only, so Pallas runs in interpret mode — wall-clock
here validates correctness-at-size and gives RELATIVE jnp-path numbers,
not TPU performance. The structural metrics (HBM bytes touched per query,
VMEM block residency) are the TPU-relevant output; wall times are labeled
as CPU-indicative only.
"""
import jax.numpy as jnp
import numpy as np

from benchmarks._timing import median_ms
from repro.core import BitPlanarDB, build_database, msb_nibble, quantize_int8
from repro.core.retrieval import stage1_scores_jnp, stage2_scores_jnp
from repro.kernels import ops


def traffic_model(n, d, c):
    """HBM bytes per query (the paper's currency)."""
    return {
        "int8_full_scan": n * d,                      # baseline
        "stage1_msb_plane": n * d // 2,               # nibble plane only
        "stage2_candidates": c * d,                   # gathered re-read
        "hier_total": n * d // 2 + c * d,
        "fused_topk_writeback": (n // 512) * 8 * 8,   # vs n*4 score dump
        "dense_score_writeback": n * 4,
    }


def run(verbose=True):
    n, d, c = 4096, 512, 50
    rng = np.random.default_rng(0)
    db = build_database(jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)))
    bp = BitPlanarDB.from_quantized(db)
    q, _ = quantize_int8(jnp.asarray(rng.normal(size=(d,)).astype(np.float32)))
    q_msb = msb_nibble(q)
    cand = jnp.arange(c, dtype=jnp.int32)
    mr = jnp.take(bp.msb_plane, cand, axis=0)
    lr = jnp.take(bp.lsb_plane, cand, axis=0)

    rows = {
        "stage1_jnp_ms": median_ms(stage1_scores_jnp, q_msb, bp.msb_plane),
        "stage1_pallas_ms": median_ms(ops.stage1_scores, q_msb, bp.msb_plane),
        "stage2_jnp_ms": median_ms(stage2_scores_jnp, q, mr, lr),
        "stage2_pallas_ms": median_ms(ops.stage2_scores, q, mr, lr),
        "fused_pallas_ms": median_ms(
            lambda a, b: ops.fused_candidates(a, b, c=c, k_per_block=8),
            q_msb, bp.msb_plane),
    }
    tm = traffic_model(n, d, c)
    if verbose:
        print("== kernel microbench (CPU: Pallas interpret mode — "
              "correctness-at-size; wall times indicative only) ==")
        for k, v in rows.items():
            print(f"  {k:>22}: {v:8.2f} ms")
        print("-- HBM traffic model per query (bytes), N=4096 D=512 C=50 --")
        for k, v in tm.items():
            print(f"  {k:>22}: {v:>10,}")
        print("  hier/int8 traffic ratio: "
              f"{tm['hier_total'] / tm['int8_full_scan']:.3f} "
              "(paper: ~0.5 at large N)")
    checks = {
        "hier traffic ~ half of int8":
            tm["hier_total"] / tm["int8_full_scan"] < 0.52,
        "fused writeback >= 32x smaller":
            tm["dense_score_writeback"] / tm["fused_topk_writeback"] >= 32,
    }
    records = {
        name: {"median_ms": rows[f"{name}_pallas_ms"],
               "ref_median_ms": rows[f"{name}_jnp_ms"],
               "ratio": rows[f"{name}_jnp_ms"] / rows[f"{name}_pallas_ms"]}
        for name in ("stage1", "stage2")
    }
    return {"times": rows, "traffic": tm, "checks": checks,
            "records": records}


if __name__ == "__main__":
    print(run()["checks"])
