"""Paper Fig. 5(b): energy per query for INT8 / INT4 / hierarchical,
on the three evaluation corpora (sizes matched to the BEIR subsets the
paper's numbers imply), plus the TPU-v5e constant set for the pod-scale
variant of the same comparison."""
from repro.core import energy as en

CORPORA = {"SciFact": 4020, "NFCorpus": 3600, "ArguAna": 8700}


def run(verbose=True):
    rows = []
    for name, n in CORPORA.items():
        row = {"corpus": name, "docs": n}
        for label, fn in (("INT8", en.cost_int8), ("INT4", en.cost_int4),
                          ("Hier", en.cost_hierarchical)):
            row[label] = fn(n).total_uj
        for label, fn in (("INT8-v5e", en.cost_int8),
                          ("Hier-v5e", en.cost_hierarchical)):
            row[label] = fn(n, consts=en.TPU_V5E).total_uj
        rows.append(row)
    if verbose:
        print("== Fig. 5(b): energy per query (uJ) ==")
        print(f"{'corpus':>10} {'docs':>6} {'INT8':>9} {'INT4':>9} "
              f"{'Hier':>9} {'Hier/INT8':>10}")
        for r in rows:
            print(f"{r['corpus']:>10} {r['docs']:>6} {r['INT8']:>9.2f} "
                  f"{r['INT4']:>9.2f} {r['Hier']:>9.2f} "
                  f"{r['Hier'] / r['INT8']:>10.3f}")
        print("(paper: hierarchical reaches INT4-level energy at INT8-level "
              "precision; SciFact hier = 337.74 uJ in Table III)")
    checks = {}
    for r in rows:
        checks[f"{r['corpus']}: int4 <= hier < int8"] = (
            r["INT4"] <= r["Hier"] < r["INT8"])
        checks[f"{r['corpus']}: hier close to int4"] = (
            r["Hier"] / r["INT4"] < 1.10)
    sci = next(r for r in rows if r["corpus"] == "SciFact")
    checks["SciFact hier ~337.74uJ (Table III)"] = (
        abs(sci["Hier"] - 337.74) / 337.74 < 0.05)
    return {"rows": rows, "checks": checks}


if __name__ == "__main__":
    print(run()["checks"])
