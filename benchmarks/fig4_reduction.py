"""Paper Fig. 4: memory-access & computation reduction vs corpus size."""
from repro.core import energy as en


def run(verbose=True):
    rows = []
    for n in (100, 200, 500, 1000, 2000, 5000, 10000):
        rows.append({"chunks": n,
                     "memory_reduction": en.memory_reduction(n),
                     "compute_reduction": en.compute_reduction(n),
                     "candidates": en.default_candidates(n)})
    if verbose:
        print("== Fig. 4: reduction vs corpus size (paper: 30->~50% mem, "
              "55->74.7% compute) ==")
        print(f"{'chunks':>8} {'cand':>5} {'mem_red':>8} {'comp_red':>9}")
        for r in rows:
            print(f"{r['chunks']:>8} {r['candidates']:>5} "
                  f"{r['memory_reduction']:>8.3f} "
                  f"{r['compute_reduction']:>9.3f}")
    first, last = rows[0], rows[-1]
    checks = {
        "mem_red@100 ~ 0.30": abs(first["memory_reduction"] - 0.30) < 0.02,
        "mem_red@10k ~ 0.50": abs(last["memory_reduction"] - 0.495) < 0.01,
        "comp_red@100 ~ 0.55": abs(first["compute_reduction"] - 0.55) < 0.02,
        "comp_red@10k ~ 0.747": abs(last["compute_reduction"] - 0.745) < 0.01,
    }
    return {"rows": rows, "checks": checks}


if __name__ == "__main__":
    out = run()
    print(out["checks"])
