"""Paper Table I / Fig. 5(a): retrieval P@1 for INT8 / INT4 / hierarchical.

BEIR (SciFact/NFCorpus/ArguAna) is not downloadable in this offline
container, so the paper's PROTOCOL is reproduced on three synthetic
"domains" of increasing difficulty (clustered near-duplicate corpora with
planted relevance; ground truth = the planted gold document). The paper's
CLAIM under test is the ordering: hierarchical ~ INT8 > INT4.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (BitPlanarDB, RetrievalConfig, build_database,
                        exact_retrieve, int4_retrieve, quantize_int8,
                        two_stage_retrieve)
from repro.data import retrieval_corpus

DOMAINS = {
    # name: (num_docs, noise, cluster_size, cluster_spread)
    "synth-easy (SciFact-like)": (1000, 0.12, 8, 0.25),
    "synth-medium (NFCorpus-like)": (1200, 0.15, 16, 0.15),
    "synth-hard (ArguAna-like)": (1400, 0.16, 24, 0.12),
}

NUM_QUERIES = 64


def p_at_k(fn, queries, gold, k=1):
    hits = 0
    for i in range(queries.shape[0]):
        qc, _ = quantize_int8(jnp.asarray(queries[i]))
        idx = np.asarray(fn(qc).indices)[:k]
        hits += int(gold[i] in idx)
    return hits / queries.shape[0]


def run(verbose=True):
    cfg = RetrievalConfig(k=5, metric="cosine")
    rows = []
    for name, (n, noise, cs, spread) in DOMAINS.items():
        docs, queries, gold = retrieval_corpus(
            n, 512, num_queries=NUM_QUERIES, noise=noise, cluster_size=cs,
            cluster_spread=spread, seed=hash(name) % 2**31)
        qdb = build_database(jnp.asarray(docs))
        bp = BitPlanarDB.from_quantized(qdb)
        row = {
            "domain": name, "docs": n,
            "INT8": p_at_k(lambda q: exact_retrieve(q, qdb, cfg), queries,
                           gold),
            "INT4": p_at_k(lambda q: int4_retrieve(q, bp, cfg), queries,
                           gold),
            "Hierarchical": p_at_k(lambda q: two_stage_retrieve(q, bp, cfg),
                                   queries, gold),
        }
        rows.append(row)
    if verbose:
        print("== Table I protocol (synthetic domains): P@1 ==")
        print(f"{'domain':>30} {'INT8':>6} {'INT4':>6} {'Hier':>6}")
        for r in rows:
            print(f"{r['domain']:>30} {r['INT8']:>6.3f} {r['INT4']:>6.3f} "
                  f"{r['Hierarchical']:>6.3f}")
        print("paper (BEIR): SciFact .507/.483/.497, NFCorpus "
              ".421/.368/.412, ArguAna .253/.248/.253")
    checks = {}
    for r in rows:
        checks[f"{r['domain']}: hier>=int4"] = (
            r["Hierarchical"] >= r["INT4"] - 1e-9)
        checks[f"{r['domain']}: hier within 0.05 of int8"] = (
            r["Hierarchical"] >= r["INT8"] - 0.05)
    return {"rows": rows, "checks": checks}


if __name__ == "__main__":
    print(run()["checks"])
