"""Paper Table I / Fig. 5(a): retrieval P@1 for INT8 / INT4 / hierarchical.

BEIR (SciFact/NFCorpus/ArguAna) is not downloadable in this offline
container, so the paper's PROTOCOL is reproduced on three synthetic
"domains" of increasing difficulty (clustered near-duplicate corpora with
planted relevance; ground truth = the planted gold document). The paper's
CLAIM under test is the ordering: hierarchical ~ INT8 > INT4.

A fourth row extends the table one precision step further down: the
ADAPTIVE-PRECISION FRONTIER, where the cluster-pruned cascade adds the
1-bit sign-plane prescreen and the survivor budget C0 shrinks from the
whole probe view to view/8 — P@1 must hold while stage-0+stage-1 bytes
drop (2x at C0 = view/4; the byte model is gated by retrieval_bench).
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (BitPlanarDB, RetrievalConfig, build_database,
                        clustering, exact_retrieve, int4_retrieve,
                        quantize_int8, two_stage_retrieve)
from repro.core.retrieval import cluster_pruned_retrieve
from repro.data import retrieval_corpus

DOMAINS = {
    # name: (num_docs, noise, cluster_size, cluster_spread)
    "synth-easy (SciFact-like)": (1000, 0.12, 8, 0.25),
    "synth-medium (NFCorpus-like)": (1200, 0.15, 16, 0.15),
    "synth-hard (ArguAna-like)": (1400, 0.16, 24, 0.12),
}

NUM_QUERIES = 64


def p_at_k(fn, queries, gold, k=1):
    hits = 0
    for i in range(queries.shape[0]):
        qc, _ = quantize_int8(jnp.asarray(queries[i]))
        idx = np.asarray(fn(qc).indices)[:k]
        hits += int(gold[i] in idx)
    return hits / queries.shape[0]


def _frontier_row():
    """P@1 of the cluster-pruned cascade as the sign-prescreen budget
    C0 shrinks: one clustered corpus, one codebook (planted centers),
    measured at C0 = view (identity), view/4 (the 2x byte point) and
    view/8."""
    n, d, cs, br, nprobe, k = 2048, 256, 64, 32, 8, 5
    docs, queries, gold = retrieval_corpus(
        n, d, num_queries=NUM_QUERIES, noise=0.12, cluster_size=cs,
        cluster_spread=0.2, seed=99)
    db = BitPlanarDB.from_quantized(build_database(jnp.asarray(docs)))
    labels = (np.arange(n) // cs).astype(np.int32)
    nc = n // cs
    centers = np.stack([docs[labels == c].mean(axis=0) for c in range(nc)])
    cents, _ = quantize_int8(jnp.asarray(centers.astype(np.float32)))
    codebook = clustering.ClusterCodebook.from_codes(cents)
    table = clustering.block_table(labels, nc, br)
    q, _ = quantize_int8(jnp.asarray(queries), per_vector=True)
    view = nprobe * table.shape[1] * br

    def p1(res):
        idx = np.asarray(res.indices)
        return float(np.mean([gold[i] in idx[i][:1]
                              for i in range(NUM_QUERIES)]))

    def cascade(c0=None):
        return cluster_pruned_retrieve(
            q, db, codebook, table, labels,
            RetrievalConfig(k=k, metric="cosine", prescreen_c0=c0),
            nprobe=nprobe, block_rows=br)

    row = {"domain": "adaptive-precision frontier", "docs": n,
           "view_rows": view, "Cascade": p1(cascade())}
    for c0 in (view, view // 4, view // 8):
        row[f"C0={c0}"] = p1(cascade(c0))
    return row, view


def run(verbose=True):
    cfg = RetrievalConfig(k=5, metric="cosine")
    rows = []
    for name, (n, noise, cs, spread) in DOMAINS.items():
        docs, queries, gold = retrieval_corpus(
            n, 512, num_queries=NUM_QUERIES, noise=noise, cluster_size=cs,
            cluster_spread=spread, seed=hash(name) % 2**31)
        qdb = build_database(jnp.asarray(docs))
        bp = BitPlanarDB.from_quantized(qdb)
        row = {
            "domain": name, "docs": n,
            "INT8": p_at_k(lambda q: exact_retrieve(q, qdb, cfg), queries,
                           gold),
            "INT4": p_at_k(lambda q: int4_retrieve(q, bp, cfg), queries,
                           gold),
            "Hierarchical": p_at_k(lambda q: two_stage_retrieve(q, bp, cfg),
                                   queries, gold),
        }
        rows.append(row)
    frontier, view = _frontier_row()
    rows.append(frontier)
    if verbose:
        print("== Table I protocol (synthetic domains): P@1 ==")
        print(f"{'domain':>30} {'INT8':>6} {'INT4':>6} {'Hier':>6}")
        for r in rows[:-1]:
            print(f"{r['domain']:>30} {r['INT8']:>6.3f} {r['INT4']:>6.3f} "
                  f"{r['Hierarchical']:>6.3f}")
        print("paper (BEIR): SciFact .507/.483/.497, NFCorpus "
              ".421/.368/.412, ArguAna .253/.248/.253")
        cols = "  ".join(f"{key} {frontier[key]:.3f}" for key in frontier
                         if key.startswith("C0=") or key == "Cascade")
        print(f"{frontier['domain']:>30} (view={view}): {cols}")
    checks = {}
    for r in rows[:-1]:
        checks[f"{r['domain']}: hier>=int4"] = (
            r["Hierarchical"] >= r["INT4"] - 1e-9)
        checks[f"{r['domain']}: hier within 0.05 of int8"] = (
            r["Hierarchical"] >= r["INT8"] - 0.05)
    checks["frontier: C0=view P@1 identical to no-prescreen cascade"] = (
        frontier[f"C0={view}"] == frontier["Cascade"])
    checks["frontier: C0=view/4 P@1 >= cascade (2x byte point)"] = (
        frontier[f"C0={view // 4}"] >= frontier["Cascade"] - 1e-9)
    checks["frontier: C0=view/8 P@1 within 0.05 of cascade"] = (
        frontier[f"C0={view // 8}"] >= frontier["Cascade"] - 0.05)
    return {"rows": rows, "checks": checks}


if __name__ == "__main__":
    print(run()["checks"])
