"""Paper Table II: per-module energy for one query over a 1 MB database."""
from repro.core import energy as en

PAPER = {"DRAM": 176.0, "SRAM": 1.72, "PE": 0.3435, "SimCalc": 0.0136,
         "Rerank": 0.0055}          # uJ


def run(verbose=True):
    cb = en.cost_hierarchical(en.docs_for_db_mb(1.0))
    ours = {"DRAM": cb.dram_pj * 1e-6, "SRAM": cb.sram_pj * 1e-6,
            "PE": cb.pe_pj * 1e-6, "SimCalc": cb.simcalc_pj * 1e-6,
            "Rerank": cb.rerank_pj * 1e-6}
    props = cb.proportions()
    if verbose:
        print("== Table II: module energy, 1 MB INT8 DB, hierarchical ==")
        print(f"{'module':>10} {'ours uJ':>10} {'paper uJ':>10} {'share':>8}")
        for k in PAPER:
            print(f"{k:>10} {ours[k]:>10.4f} {PAPER[k]:>10.4f} "
                  f"{props[{'DRAM':'DRAM','SRAM':'SRAM','PE':'PE','SimCalc':'SimCalc','Rerank':'Rerank'}[k]]:>8.4f}")
        print(f"{'total':>10} {cb.total_uj:>10.2f} {178.08:>10.2f}")
        print("(PE/SimCalc/Rerank use documented bit-accounting formulas; "
              "the paper does not publish theirs — all three are <0.25% of "
              "total. DRAM/SRAM/total match to <3%.)")
    checks = {
        "DRAM within 1%": abs(ours["DRAM"] - PAPER["DRAM"]) / PAPER["DRAM"] < 0.01,
        "SRAM within 5%": abs(ours["SRAM"] - PAPER["SRAM"]) / PAPER["SRAM"] < 0.05,
        "total ~177.76uJ": abs(cb.total_uj - 177.76) / 177.76 < 0.01,
        "DRAM share ~98.8%": abs(props["DRAM"] - 0.98831) < 0.002,
    }
    return {"ours": ours, "paper": PAPER, "total_uj": cb.total_uj,
            "checks": checks}


if __name__ == "__main__":
    print(run()["checks"])
