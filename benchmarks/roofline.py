"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Per (arch x shape x mesh) cell:
    compute term    = dot_FLOPs_per_device / peak_FLOPs        [s/step]
    memory term     = HBM_bytes_per_device / HBM_bw            [s/step]
    collective term = collective_bytes_per_device / ICI link bw [s/step]

Sources: dot_FLOPs and collective bytes come from the while-aware HLO
analysis (repro.launch.hlo_analysis) of compiled.as_text() — XLA's own
cost_analysis counts scan bodies once, so it is recorded but NOT used.
HBM bytes = per-device argument + output sizes from memory_analysis()
(params + optimizer + caches + batch — the streaming-dominant traffic)
plus a documented activation-traffic estimate (saved residuals for
rematerialized training, one pass for prefill).

MODEL_FLOPS = 6·N·D (train) / 2·N·D (serve), N = ACTIVE params — the
"useful work"; the ratio MODEL_FLOPS/HLO_FLOPs surfaces remat/redundancy.
Roofline fraction = model-useful compute time / max(all three terms).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")


def _active_params(cfg) -> float:
    """Active parameter count (MoE: top-1 => 1/E of routed experts)."""
    import jax
    from repro.models import get_model
    api = get_model(cfg)
    tree = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    total = 0.0
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        keys = [getattr(e, "key", getattr(e, "name", None)) for e in path]
        n = float(leaf.size)
        if "moe" in keys and any(k in ("w_gate", "w_up", "w_down")
                                 for k in keys):
            n /= cfg.num_experts          # top-1 routing
        total += n
    return total


def _tokens(case_name: str, shape) -> float:
    return {"train_4k": shape.batch * shape.seq,
            "prefill_32k": shape.batch * shape.seq,
            "decode_32k": shape.batch * 1.0,
            "long_500k": shape.batch * 1.0}[case_name]


def model_flops(arch: str, shape_name: str) -> float:
    from repro.configs import get_config
    from repro.launch.shapes import SHAPES
    cfg = get_config(arch)
    n = _active_params(cfg)
    toks = _tokens(shape_name, SHAPES[shape_name])
    mult = 6.0 if shape_name == "train_4k" else 2.0
    return mult * n * toks


def act_bytes_estimate(arch: str, shape_name: str, devices: int) -> float:
    """Activation HBM traffic per device (documented napkin model):
    train: 3 passes (fwd/bwd/remat-fwd) x L x tokens_dev x 4D x 2B;
    prefill: 1 pass; decode: negligible (single token)."""
    from repro.configs import get_config
    from repro.launch.shapes import SHAPES
    cfg = get_config(arch)
    case = SHAPES[shape_name]
    if case.kind == "decode":
        return 0.0
    dp = min(devices, 16 * (devices // 256))   # batch-sharded ways
    toks_dev = case.batch * case.seq / max(dp, 1)
    passes = 3.0 if case.kind == "train" else 1.0
    return passes * cfg.num_layers * toks_dev * 4 * cfg.d_model * 2


def analyze_cell(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
    devices = rec["devices"]
    mem = rec.get("memory", {})
    hbm_bytes = (mem.get("argument_size_in_bytes", 0)
                 + mem.get("output_size_in_bytes", 0)
                 + act_bytes_estimate(arch, shape, devices))
    compute_s = rec["dot_flops"] / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    coll_s = rec["collectives"]["total"] / ICI_BW
    bound_s = max(compute_s, memory_s, coll_s)
    dominant = {compute_s: "compute", memory_s: "memory",
                coll_s: "collective"}[bound_s]
    mf = model_flops(arch, shape)
    useful_s = (mf / devices) / PEAK_FLOPS
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "devices": devices,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "bound_s": bound_s, "dominant": dominant,
        "model_flops": mf, "hlo_flops_dev": rec["dot_flops"],
        "useful_ratio": (mf / devices) / max(rec["dot_flops"], 1.0),
        "roofline_fraction": useful_s / bound_s if bound_s else 0.0,
    }


def run(verbose=True, results_path=RESULTS, mesh="single"):
    with open(results_path) as f:
        recs = json.load(f)
    rows = [analyze_cell(r) for r in recs
            if r.get("mesh") == mesh]
    rows = [r for r in rows if r]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    if verbose:
        print(f"== Roofline ({mesh} pod, per device) ==")
        hdr = (f"{'arch':>26} {'shape':>11} {'compute_s':>10} "
               f"{'memory_s':>9} {'coll_s':>9} {'bound':>10} "
               f"{'useful':>7} {'roofl%':>7}")
        print(hdr)
        for r in rows:
            print(f"{r['arch']:>26} {r['shape']:>11} "
                  f"{r['compute_s']:>10.4f} {r['memory_s']:>9.4f} "
                  f"{r['collective_s']:>9.4f} {r['dominant']:>10} "
                  f"{r['useful_ratio']:>7.2f} "
                  f"{100 * r['roofline_fraction']:>6.1f}%")
    checks = {"all cells analyzed": len(rows) >= 30}
    return {"rows": rows, "checks": checks}


if __name__ == "__main__":
    run()
